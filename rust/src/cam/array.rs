//! Analog CAM arrays and the core's stacked/queued macro-array (§III, Fig. 4).
//!
//! A physical array is `H × W` macro-cells (chip parameter: 128 × 65).
//! Each X-TIME core combines:
//!  * `N_stacked = 2` arrays extended row-wise (256 addressable words), and
//!  * `N_queued = 2` arrays extended column-wise (130 features), whose
//!    match lines are ANDed by selectively pre-charging array `i+1` only on
//!    rows matched in array `i`.
//!
//! The functional semantics is a row-wise interval match over the full
//! word; the queued decomposition matters for the latency/energy model
//! (only matched rows of array `i+1` are charged).
//!
//! Every search entry point converts query levels through [`dac_level`]:
//! the DAC saturates at full scale, so a level past the top 8-bit level
//! (e.g. 256 from a +1 DAC perturbation of 255, or an 8-bit-scaled
//! out-of-range bin) drives level 255 — it must never wrap to level 0.
//! All three search variants share this conversion so they stay
//! mutually equivalent on every input.

use super::cell::{MacroCell, MACRO_BINS};

/// DAC input conversion: query levels saturate at the top 8-bit level.
/// (A bare `as u8` cast here once wrapped level 256 to level 0 and
/// silently matched low windows instead of top windows.) Public because
/// the functional engine's bin→level scaling shares it
/// (`CamEngine::scale_bin`): every path that turns a quantizer bin or a
/// scaled query into a DAC level must use this one conversion so the
/// scalar, indexed and planned paths stay mutually equivalent on every
/// input, including out-of-range bins.
#[inline]
pub fn dac_level(q: u16) -> u16 {
    q.min(MACRO_BINS - 1)
}

/// Physical array geometry at 16 nm (paper §III-C, ref [38]).
pub const ARRAY_ROWS: usize = 128;
pub const ARRAY_COLS: usize = 65;
/// Core macro-array: 2 stacked × 2 queued physical arrays.
pub const N_STACKED: usize = 2;
pub const N_QUEUED: usize = 2;
pub const CORE_ROWS: usize = ARRAY_ROWS * N_STACKED; // 256 words
pub const CORE_COLS: usize = ARRAY_COLS * N_QUEUED; // 130 features

/// A dense array of macro-cells (row-major).
#[derive(Clone, Debug)]
pub struct CamArray {
    pub n_rows: usize,
    pub n_cols: usize,
    pub cells: Vec<MacroCell>,
}

impl CamArray {
    /// All-don't-care array.
    pub fn dont_care(n_rows: usize, n_cols: usize) -> CamArray {
        CamArray { n_rows, n_cols, cells: vec![MacroCell::DONT_CARE; n_rows * n_cols] }
    }

    /// Never-matching array (inverted windows — padding rows).
    pub fn never(n_rows: usize, n_cols: usize) -> CamArray {
        CamArray {
            n_rows,
            n_cols,
            cells: vec![MacroCell::new(crate::cam::cell::MACRO_BINS, 0); n_rows * n_cols],
        }
    }

    #[inline]
    pub fn cell(&self, row: usize, col: usize) -> &MacroCell {
        &self.cells[row * self.n_cols + col]
    }

    pub fn cell_mut(&mut self, row: usize, col: usize) -> &mut MacroCell {
        &mut self.cells[row * self.n_cols + col]
    }

    /// Ideal single-shot search: per-row match of `query` (8-bit bins).
    /// `query.len()` may be shorter than `n_cols`; missing trailing
    /// features are treated as don't care (they are padding columns).
    pub fn search_ideal(&self, query: &[u16], out: &mut Vec<bool>) {
        out.clear();
        let w = query.len().min(self.n_cols);
        for r in 0..self.n_rows {
            let base = r * self.n_cols;
            let mut m = true;
            for (c, q) in query.iter().take(w).enumerate() {
                if !self.cells[base + c].matches_ideal(dac_level(*q)) {
                    m = false;
                    break;
                }
            }
            out.push(m);
        }
    }

    /// Two-cycle macro-cell search (the hardware path). Equivalent to
    /// [`CamArray::search_ideal`] on every input — asserted by tests.
    pub fn search_two_cycle(&self, query: &[u16], out: &mut Vec<bool>) {
        out.clear();
        let w = query.len().min(self.n_cols);
        for r in 0..self.n_rows {
            let base = r * self.n_cols;
            // MAL precharged high; both cycles must hold on every cell.
            let mut mal = true;
            for (c, q) in query.iter().take(w).enumerate() {
                let (c1, c2) = self.cells[base + c].search_cycles(dac_level(*q) as u8);
                if !(c1 && c2) {
                    mal = false;
                    break;
                }
            }
            out.push(mal);
        }
    }

    /// Number of rows whose match line would be charged during a search
    /// where only `precharged` rows are active (queued-array model).
    pub fn search_gated(&self, query: &[u16], precharged: &[bool], out: &mut Vec<bool>) {
        out.clear();
        let w = query.len().min(self.n_cols);
        for r in 0..self.n_rows {
            if !precharged[r] {
                out.push(false);
                continue;
            }
            let base = r * self.n_cols;
            let mut m = true;
            for (c, q) in query.iter().take(w).enumerate() {
                if !self.cells[base + c].matches_ideal(dac_level(*q)) {
                    m = false;
                    break;
                }
            }
            out.push(m);
        }
    }
}

/// A core's full CAM macro: logical `CORE_ROWS × CORE_COLS` view split into
/// queued segments for the pipeline/energy model.
#[derive(Clone, Debug)]
pub struct CoreCam {
    /// One logical array per queued segment, each `n_rows × ARRAY_COLS`.
    pub segments: Vec<CamArray>,
    pub n_rows: usize,
    pub n_cols: usize,
}

/// Result of a gated core search: final match vector plus per-segment
/// charged-row counts (for the energy model).
pub struct CoreSearch {
    pub matches: Vec<bool>,
    pub charged_rows: Vec<usize>,
}

impl CoreCam {
    /// Build from a logical bounds matrix `[n_rows × n_cols]` of macro-cells.
    pub fn from_cells(n_rows: usize, n_cols: usize, cells: Vec<MacroCell>) -> CoreCam {
        assert!(n_rows <= CORE_ROWS, "core overflow: {n_rows} rows");
        assert!(n_cols <= CORE_COLS, "core overflow: {n_cols} features");
        assert_eq!(cells.len(), n_rows * n_cols);
        let n_segments = n_cols.div_ceil(ARRAY_COLS).max(1);
        let mut segments = Vec::with_capacity(n_segments);
        for s in 0..n_segments {
            let c0 = s * ARRAY_COLS;
            let c1 = ((s + 1) * ARRAY_COLS).min(n_cols);
            let mut seg = CamArray::dont_care(n_rows, c1 - c0);
            for r in 0..n_rows {
                for c in c0..c1 {
                    *seg.cell_mut(r, c - c0) = cells[r * n_cols + c];
                }
            }
            segments.push(seg);
        }
        CoreCam { segments, n_rows, n_cols }
    }

    /// Search the full word: segment 0 searches all rows; segment `i+1`
    /// pre-charges only rows matched by segment `i` (§III-A "only
    /// previously matched lines are charged").
    pub fn search(&self, query: &[u16]) -> CoreSearch {
        let mut active = vec![true; self.n_rows];
        let mut charged = Vec::with_capacity(self.segments.len());
        let mut out = Vec::new();
        for (s, seg) in self.segments.iter().enumerate() {
            let c0 = s * ARRAY_COLS;
            let c1 = (c0 + seg.n_cols).min(query.len());
            let q = if c0 < query.len() { &query[c0..c1] } else { &[] };
            charged.push(active.iter().filter(|&&a| a).count());
            seg.search_gated(q, &active, &mut out);
            std::mem::swap(&mut active, &mut out);
        }
        CoreSearch { matches: active, charged_rows: charged }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cam::cell::MACRO_BINS;
    use crate::util::prop;

    fn random_array(g: &mut prop::Gen, rows: usize, cols: usize) -> CamArray {
        let mut a = CamArray::dont_care(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let lo = g.usize_in(0, 200) as u16;
                let hi = (lo as usize + g.usize_in(0, 80)) as u16;
                *a.cell_mut(r, c) = MacroCell::new(lo, hi.min(MACRO_BINS));
            }
        }
        a
    }

    #[test]
    fn two_cycle_search_equals_ideal() {
        prop::check(200, 0xA22A, |g| {
            let rows = g.usize_in(1, 24);
            let cols = g.usize_in(1, 12);
            let a = random_array(g, rows, cols);
            let q: Vec<u16> = (0..cols).map(|_| g.u8() as u16).collect();
            let mut ideal = Vec::new();
            let mut twoc = Vec::new();
            a.search_ideal(&q, &mut ideal);
            a.search_two_cycle(&q, &mut twoc);
            prop::require(ideal == twoc, format!("rows={rows} cols={cols}"))
        });
    }

    #[test]
    fn queries_saturate_at_full_scale_on_every_search_path() {
        // Regression: a query level of 256 — e.g. a +1 DAC perturbation
        // of level 255, or an 8-bit-scaled out-of-range bin of a 4-bit
        // program — used to alias to level 0 through a wrapping `as u8`
        // cast in `search_two_cycle` and silently match low windows. The
        // DAC saturates instead (level 256 behaves as the top level 255),
        // and all three search variants must agree on it.
        let mut a = CamArray::dont_care(2, 1);
        *a.cell_mut(0, 0) = MacroCell::new(0, 10); // only low levels
        *a.cell_mut(1, 0) = MacroCell::new(200, MACRO_BINS); // top window
        let mut ideal = Vec::new();
        let mut twoc = Vec::new();
        let mut gated = Vec::new();
        // 255 (in range), 256 (the boundary) and one past it.
        for q in [MACRO_BINS - 1, MACRO_BINS, MACRO_BINS + 1] {
            a.search_ideal(&[q], &mut ideal);
            a.search_two_cycle(&[q], &mut twoc);
            a.search_gated(&[q], &[true, true], &mut gated);
            assert_eq!(ideal, vec![false, true], "q={q} must saturate, not wrap to 0");
            assert_eq!(twoc, ideal, "q={q}: two-cycle diverged from ideal");
            assert_eq!(gated, ideal, "q={q}: gated diverged from ideal");
        }
    }

    #[test]
    fn dont_care_array_matches_all() {
        let a = CamArray::dont_care(8, 4);
        let mut out = Vec::new();
        a.search_ideal(&[0, 255, 17, 99], &mut out);
        assert!(out.iter().all(|&m| m));
    }

    #[test]
    fn never_array_matches_none() {
        let a = CamArray::never(8, 4);
        let mut out = Vec::new();
        a.search_ideal(&[0, 255, 17, 99], &mut out);
        assert!(out.iter().all(|&m| !m));
    }

    #[test]
    fn core_segmentation_preserves_semantics() {
        // A CoreCam over >65 features must produce the same matches as a
        // flat row-wise check (the logical-AND equivalence of §III-A).
        prop::check(60, 0xC02E, |g| {
            let rows = g.usize_in(1, 32);
            let cols = g.usize_in(66, 130);
            let mut cells = Vec::with_capacity(rows * cols);
            for _ in 0..rows * cols {
                let lo = g.usize_in(0, 220) as u16;
                let hi = (lo as usize + g.usize_in(1, 60)) as u16;
                cells.push(MacroCell::new(lo, hi.min(MACRO_BINS)));
            }
            let q: Vec<u16> = (0..cols).map(|_| g.u8() as u16).collect();
            // Flat reference.
            let flat: Vec<bool> = (0..rows)
                .map(|r| (0..cols).all(|c| cells[r * cols + c].matches_ideal(q[c])))
                .collect();
            let core = CoreCam::from_cells(rows, cols, cells);
            let got = core.search(&q);
            prop::require(
                got.matches == flat,
                format!("rows={rows} cols={cols}"),
            )?;
            // Segment 0 always pre-charges every row.
            prop::require(got.charged_rows[0] == rows, "first segment charges all rows")
        });
    }

    #[test]
    fn gating_reduces_charged_rows() {
        // With tight first-segment windows, the second segment must charge
        // at most as many rows as the first matched.
        let rows = 64;
        let cols = 130;
        let mut cells = vec![MacroCell::DONT_CARE; rows * cols];
        // First feature only matches q=5 on even rows.
        for r in 0..rows {
            cells[r * cols] =
                if r % 2 == 0 { MacroCell::new(5, 6) } else { MacroCell::new(100, 101) };
        }
        let core = CoreCam::from_cells(rows, cols, cells);
        let mut q = vec![0u16; cols];
        q[0] = 5;
        let s = core.search(&q);
        assert_eq!(s.charged_rows[0], rows);
        assert_eq!(s.charged_rows[1], rows / 2);
        assert_eq!(s.matches.iter().filter(|&&m| m).count(), rows / 2);
    }

    #[test]
    #[should_panic(expected = "core overflow")]
    fn overflow_rows_panics() {
        CoreCam::from_cells(CORE_ROWS + 1, 4, vec![MacroCell::DONT_CARE; (CORE_ROWS + 1) * 4]);
    }
}
