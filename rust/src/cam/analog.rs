//! Device-level analog model: memristor conductances, match-line
//! discharge dynamics and the defect-rate derivation (paper §IV-B, §V-A).
//!
//! The level-flip abstraction used by [`super::defects`] is *derived* here
//! from physical quantities: stored 4-bit levels map to conductances in
//! the paper's 1–100 µS window, programming noise is Gaussian with
//! σ ≈ 1 µS [50][51], and a stored level "flips" when the programmed
//! conductance lands closer to a neighbouring level's nominal value.
//! The paper quotes ~0.2% flip probability for these numbers; the unit
//! tests reproduce that figure from first principles.
//!
//! The discharge-time model backs the timing constants of
//! [`crate::sim::ChipConfig`]: a mismatching cell sinks `I ≈ G·V_ML`,
//! discharging the match line below the sense threshold well within the
//! 1 ns search cycle for any conductance in the window, while parasitics
//! bound the pre-charge time — the basis for λ_CAM's cycle budget and the
//! ~1 GHz clock [38][39].

use crate::util::Rng;

/// Conductance window of the TaOx devices (Siemens).
pub const G_MIN_S: f64 = 1e-6;
pub const G_MAX_S: f64 = 100e-6;
/// Programming noise σ (Siemens), conservative per §V-A.
pub const G_SIGMA_S: f64 = 1e-6;
/// Device levels (4-bit).
pub const N_LEVELS: usize = 16;

/// Match-line RC parameters at 16 nm (order-of-magnitude estimates from
/// [38]: 128-row × 65-col arrays show < 1 ns access). The MAL is
/// segmented per queued array (§III-A), so the capacitance seen by one
/// search is a short 65-cell wire segment.
pub const ML_CAPACITANCE_F: f64 = 1.5e-15; // ~1.5 fF per 65-cell segment
pub const ML_PRECHARGE_V: f64 = 0.8;
pub const SENSE_THRESHOLD_V: f64 = 0.4;

/// Nominal conductance of a stored level: uniform spacing across the
/// window (the programming target grid).
pub fn level_conductance(level: usize) -> f64 {
    assert!(level < N_LEVELS);
    G_MIN_S + (G_MAX_S - G_MIN_S) * level as f64 / (N_LEVELS - 1) as f64
}

/// Half the inter-level spacing: the decision boundary for read-out.
pub fn level_margin() -> f64 {
    0.5 * (G_MAX_S - G_MIN_S) / (N_LEVELS - 1) as f64
}

/// Nearest stored level for a programmed conductance (read-out model).
pub fn conductance_level(g: f64) -> usize {
    let step = (G_MAX_S - G_MIN_S) / (N_LEVELS - 1) as f64;
    (((g - G_MIN_S) / step).round().clamp(0.0, (N_LEVELS - 1) as f64)) as usize
}

/// Program a level with Gaussian noise; returns the achieved conductance.
pub fn program_level(level: usize, rng: &mut Rng) -> f64 {
    (level_conductance(level) + G_SIGMA_S * rng.normal()).clamp(0.2e-6, 120e-6)
}

/// Analytic single-device flip probability: P(|noise| > margin) for a
/// Gaussian with σ = `G_SIGMA_S`. With margin = 3.3 µS and σ = 1 µS this
/// is ≈ 0.1–0.3% — the paper's "~0.2%" operating point.
pub fn analytic_flip_probability() -> f64 {
    let z = level_margin() / G_SIGMA_S;
    2.0 * gaussian_tail(z)
}

/// Q-function via Abramowitz–Stegun erfc approximation.
fn gaussian_tail(z: f64) -> f64 {
    0.5 * erfc(z / std::f64::consts::SQRT_2)
}

fn erfc(x: f64) -> f64 {
    // A&S 7.1.26, |ε| ≤ 1.5e-7.
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let e = poly * (-x * x).exp();
    if x >= 0.0 {
        e
    } else {
        2.0 - e
    }
}

/// Monte-Carlo flip rate over `n` program–read cycles.
pub fn measured_flip_rate(n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut flips = 0usize;
    for i in 0..n {
        let level = i % N_LEVELS;
        let g = program_level(level, &mut rng);
        if conductance_level(g) != level {
            flips += 1;
        }
    }
    flips as f64 / n as f64
}

/// Match-line discharge time through a mismatching cell of conductance
/// `g`: τ = C·ΔV / (G·V) (linearized constant-current estimate).
pub fn discharge_time_s(g: f64) -> f64 {
    let dv = ML_PRECHARGE_V - SENSE_THRESHOLD_V;
    ML_CAPACITANCE_F * dv / (g * ML_PRECHARGE_V)
}

/// Worst-case (weakest-conductance) discharge time — must fit in one
/// search cycle for the λ_CAM budget to hold.
pub fn worst_case_discharge_s() -> f64 {
    discharge_time_s(G_MIN_S)
}

/// Sharpness of the soft match boundary (logistic slope, 1/margin
/// units). MoS₂ soft-boundary CAMs (arXiv 2507.12384) report a graded,
/// roughly sigmoidal match-line response near the stored interval edge
/// instead of the hard step an ideal TCAM gives; β = 4 places the
/// 98%-confidence point at a margin of ~1 decision unit, matching the
/// "one quantizer bin ≈ one level margin" scale of the 8-bit deploy grid.
pub const SOFT_BOUNDARY_BETA: f64 = 4.0;

/// Soft-boundary confidence for a decision made at distance `margin`
/// from the class boundary (see [`crate::data::Task::decision_margin`]):
/// the logistic response σ(β·margin) of a soft match boundary.
///
/// * `margin = 0` (on the boundary) → 0.5: a coin flip.
/// * `margin → ∞` (regression / far from the boundary) → 1.0.
/// * NaN margins (defect-corrupted accumulators) → 0.0, so corrupted
///   rows surface as zero-confidence instead of poisoning a mean.
///
/// Monotone in `margin`; used by the serving layer to flag low-confidence
/// rows while a repair is in flight (degraded-serving mode).
pub fn soft_confidence(margin: f32) -> f32 {
    if margin.is_nan() {
        return 0.0;
    }
    let m = margin as f64;
    (1.0 / (1.0 + (-SOFT_BOUNDARY_BETA * m).exp())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_grid_monotone_and_bounded() {
        let mut prev = 0.0;
        for l in 0..N_LEVELS {
            let g = level_conductance(l);
            assert!(g > prev);
            assert!((G_MIN_S..=G_MAX_S).contains(&g));
            prev = g;
        }
        assert_eq!(level_conductance(0), G_MIN_S);
        assert_eq!(level_conductance(N_LEVELS - 1), G_MAX_S);
    }

    #[test]
    fn readout_roundtrip_without_noise() {
        for l in 0..N_LEVELS {
            assert_eq!(conductance_level(level_conductance(l)), l);
        }
    }

    #[test]
    fn paper_flip_probability_operating_point() {
        // §V-A: σ = 1 µS on the 1–100 µS window → ~0.2% flip probability.
        let analytic = analytic_flip_probability();
        assert!(
            (0.0005..0.005).contains(&analytic),
            "analytic flip probability {analytic}"
        );
        let measured = measured_flip_rate(200_000, 42);
        // Monte-Carlo agrees with the analytic tail within 30%.
        assert!(
            (measured - analytic).abs() < 0.3 * analytic + 2e-4,
            "measured {measured} vs analytic {analytic}"
        );
    }

    #[test]
    fn flips_move_one_level_only() {
        // With σ ≪ level spacing, flips land on adjacent levels — the
        // justification for the ±1-level defect model in `defects.rs`.
        let mut rng = Rng::new(7);
        for _ in 0..50_000 {
            let level = 1 + (rng.below(N_LEVELS - 2));
            let g = program_level(level, &mut rng);
            let read = conductance_level(g);
            assert!((read as i32 - level as i32).abs() <= 1, "{level} → {read}");
        }
    }

    #[test]
    fn discharge_fits_the_search_cycle() {
        // Even the weakest mismatching device must discharge the ML well
        // inside the 1 ns cycle at 1 GHz (paper forecasts 100 ps searches
        // for strong conductances).
        let worst = worst_case_discharge_s();
        assert!(worst < 1e-9, "worst-case discharge {worst} s");
        let best = discharge_time_s(G_MAX_S);
        assert!(best < 100e-12, "best-case discharge {best} s (paper forecasts ~100 ps)");
        assert!(best < worst);
    }

    #[test]
    fn erfc_sanity() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-6);
        assert!(erfc(3.0) < 3e-5);
        assert!((erfc(-3.0) - 2.0).abs() < 3e-5);
    }

    #[test]
    fn soft_confidence_shape() {
        // Boundary → coin flip; monotone; saturates to 1; NaN → 0.
        assert!((soft_confidence(0.0) - 0.5).abs() < 1e-6);
        let mut prev = 0.0f32;
        for m in [0.01f32, 0.1, 0.5, 1.0, 2.0, 10.0] {
            let c = soft_confidence(m);
            assert!(c > prev, "confidence not monotone at margin {m}");
            assert!(c <= 1.0);
            prev = c;
        }
        assert!(soft_confidence(f32::INFINITY) == 1.0);
        assert_eq!(soft_confidence(f32::NAN), 0.0);
        // Symmetric distrust below the boundary (never used in serving,
        // but keeps the function total).
        assert!(soft_confidence(-1.0) < 0.5);
    }
}
