//! Analog defect injection (paper §V-A, Fig. 9b).
//!
//! A *defect* is a single-level random flip in either
//!  * a memristor conductance — one of the four devices of a macro-cell
//!    (lower/upper bound × MSB/LSB sub-cell) moves one level up or down, or
//!  * a DAC output — the analog query voltage applied on one data line is
//!    one level off.
//!
//! Following the paper's protocol, a fraction `pct` of devices is selected
//! uniformly at random, half flipped up and half down, and accuracy is
//! averaged over many independent draws.

use super::cell::{MacroCell, SUB_LEVELS};
use crate::util::Rng;

/// Defect-injection configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DefectSpec {
    /// Fraction of memristor devices flipped (0.0 – 1.0).
    pub memristor_pct: f64,
    /// Fraction of DAC channels flipped (0.0 – 1.0).
    pub dac_pct: f64,
}

impl DefectSpec {
    pub const NONE: DefectSpec = DefectSpec { memristor_pct: 0.0, dac_pct: 0.0 };

    pub fn memristor(pct: f64) -> DefectSpec {
        DefectSpec { memristor_pct: pct, dac_pct: 0.0 }
    }

    pub fn dac(pct: f64) -> DefectSpec {
        DefectSpec { memristor_pct: 0.0, dac_pct: pct }
    }
}

/// Flip one sub-cell level up/down, clamping to the device range.
/// Level space is 0..=16 (16 = "programmed past last level" upper bound).
fn flip_level(level: u16, up: bool) -> u16 {
    if up {
        (level + 1).min(SUB_LEVELS)
    } else {
        level.saturating_sub(1)
    }
}

/// Perturb stored macro-cells in place: each of the 4 devices per cell is
/// independently selected with probability `pct`; selected devices flip
/// one level, alternating up/down draws (half up, half down in
/// expectation, as in the paper).
pub fn inject_memristor_defects(cells: &mut [MacroCell], pct: f64, rng: &mut Rng) {
    let _ = inject_memristor_defects_tracked(cells, pct, rng);
}

/// Like [`inject_memristor_defects`] but also reports *which* cells ended
/// up with different stored bounds (indices into `cells`). A selected
/// device whose flip clamps to a no-op (already at the range edge) is not
/// reported — only cells whose programmed window actually changed. Both
/// functions consume the identical `rng` stream, so a tracked replay of
/// an engine's defect draw identifies exactly the rows that engine
/// perturbed — the basis of `compiler::defect_affected_trees` and the
/// defect-aware retrain loop (`trees::hat`).
pub fn inject_memristor_defects_tracked(
    cells: &mut [MacroCell],
    pct: f64,
    rng: &mut Rng,
) -> Vec<usize> {
    if pct <= 0.0 {
        return Vec::new();
    }
    let mut changed = Vec::new();
    for (idx, cell) in cells.iter_mut().enumerate() {
        let [(mut lm, mut ll), (mut hm, mut hl)] = cell.sub_cells();
        for dev in 0..4u8 {
            if rng.chance(pct) {
                let up = rng.chance(0.5);
                match dev {
                    0 => lm = flip_level(lm, up),
                    1 => ll = flip_level(ll, up),
                    2 => hm = flip_level(hm, up),
                    _ => hl = flip_level(hl, up),
                }
            }
        }
        let perturbed = MacroCell::from_levels(lm, ll, hm, hl);
        if perturbed != *cell {
            changed.push(idx);
        }
        *cell = perturbed;
    }
    changed
}

/// Per-column DAC error table for one core: offset applied to the query's
/// MSB/LSB level on that data line (−1, 0, +1).
#[derive(Clone, Debug)]
pub struct DacErrors {
    pub msb_off: Vec<i8>,
    pub lsb_off: Vec<i8>,
}

impl DacErrors {
    pub fn none(n_cols: usize) -> DacErrors {
        DacErrors { msb_off: vec![0; n_cols], lsb_off: vec![0; n_cols] }
    }

    /// Draw a defect table: each DAC channel (2 per column: MSB and LSB
    /// line drivers) flips with probability `pct`.
    pub fn draw(n_cols: usize, pct: f64, rng: &mut Rng) -> DacErrors {
        let mut d = DacErrors::none(n_cols);
        if pct <= 0.0 {
            return d;
        }
        for c in 0..n_cols {
            if rng.chance(pct) {
                d.msb_off[c] = if rng.chance(0.5) { 1 } else { -1 };
            }
            if rng.chance(pct) {
                d.lsb_off[c] = if rng.chance(0.5) { 1 } else { -1 };
            }
        }
        d
    }

    pub fn is_clean(&self) -> bool {
        self.msb_off.iter().all(|&o| o == 0) && self.lsb_off.iter().all(|&o| o == 0)
    }

    /// Apply to an 8-bit query bin: the MSB DAC shifts by 16 bins, the LSB
    /// DAC by 1, clamped to the representable range.
    pub fn apply(&self, col: usize, q: u16) -> u16 {
        let mut v = q as i32;
        if col < self.msb_off.len() {
            v += self.msb_off[col] as i32 * SUB_LEVELS as i32;
            v += self.lsb_off[col] as i32;
        }
        v.clamp(0, 255) as u16
    }

    /// Apply to a full query row.
    pub fn apply_row(&self, q: &[u16]) -> Vec<u16> {
        q.iter().enumerate().map(|(c, &v)| self.apply(c, v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cam::cell::MACRO_BINS;

    #[test]
    fn zero_pct_is_identity() {
        let mut cells = vec![MacroCell::new(10, 200), MacroCell::new(0, MACRO_BINS)];
        let orig = cells.clone();
        let mut rng = Rng::new(1);
        inject_memristor_defects(&mut cells, 0.0, &mut rng);
        assert_eq!(cells, orig);
        let d = DacErrors::draw(8, 0.0, &mut rng);
        assert!(d.is_clean());
        assert_eq!(d.apply(3, 77), 77);
    }

    #[test]
    fn flip_moves_exactly_one_level() {
        // With pct=1 every device flips; bound moves by ±1 (LSB) and/or
        // ±16 (MSB) level-equivalents.
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let lo = rng.below(240) as u16;
            let hi = lo + rng.below(16) as u16 + 1;
            let mut cells = vec![MacroCell::new(lo, hi)];
            inject_memristor_defects(&mut cells, 1.0, &mut rng);
            let d_lo = (cells[0].lo as i32 - lo as i32).abs();
            let d_hi = (cells[0].hi as i32 - hi as i32).abs();
            // Each bound has one MSB (±16) and one LSB (±1) flip; combined
            // displacement ∈ {15, 16, 17} or cancelled edge clamps ≤ 17.
            assert!(d_lo <= 17, "lo moved {d_lo}");
            assert!(d_hi <= 17, "hi moved {d_hi}");
        }
    }

    #[test]
    fn defect_rate_statistics() {
        // At pct = 0.1 about 10% of devices flip → measure on many cells.
        let n = 20_000;
        let mut cells = vec![MacroCell::new(64, 192); n];
        let mut rng = Rng::new(3);
        inject_memristor_defects(&mut cells, 0.1, &mut rng);
        let changed = cells.iter().filter(|c| **c != MacroCell::new(64, 192)).count();
        // 64 = (4,0) and 192 = (12,0): the two LSB devices sit at level 0,
        // so their down-flips clamp to no-ops. Effective change prob:
        // 1 − (1−p)² · (1−p/2)² ≈ 0.269 at p = 0.1.
        let frac = changed as f64 / n as f64;
        assert!((0.24..0.30).contains(&frac), "changed fraction {frac}");
    }

    #[test]
    fn dac_offsets_shift_query() {
        let d = DacErrors { msb_off: vec![1, -1, 0], lsb_off: vec![0, 1, -1] };
        assert_eq!(d.apply(0, 100), 116);
        assert_eq!(d.apply(1, 100), 85);
        assert_eq!(d.apply(2, 0), 0); // clamped
        assert_eq!(d.apply(2, 255), 254);
    }

    #[test]
    fn levels_clamp_at_range_edges() {
        let mut cells = vec![MacroCell::new(0, MACRO_BINS)];
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            inject_memristor_defects(&mut cells, 1.0, &mut rng);
            assert!(cells[0].lo <= MACRO_BINS && cells[0].hi <= MACRO_BINS);
        }
    }

    #[test]
    fn tracked_injection_matches_untracked_stream() {
        // Tracked and untracked injection must perturb identically from
        // the same seed (tracked is the replay tool for engine draws).
        let mk = |tracked: bool| {
            let mut cells = vec![MacroCell::new(40, 120); 256];
            let mut rng = Rng::new(2024);
            if tracked {
                let changed = inject_memristor_defects_tracked(&mut cells, 0.2, &mut rng);
                (cells, changed)
            } else {
                inject_memristor_defects(&mut cells, 0.2, &mut rng);
                (cells, Vec::new())
            }
        };
        let (a, changed) = mk(true);
        let (b, _) = mk(false);
        assert_eq!(a, b, "tracked injection drifted from the untracked stream");
        // The report lists exactly the cells that differ from the original.
        assert!(!changed.is_empty());
        for (i, c) in a.iter().enumerate() {
            let is_changed = *c != MacroCell::new(40, 120);
            assert_eq!(changed.contains(&i), is_changed, "cell {i}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut cells = vec![MacroCell::new(30, 99); 64];
            let mut rng = Rng::new(77);
            inject_memristor_defects(&mut cells, 0.3, &mut rng);
            cells
        };
        assert_eq!(mk(), mk());
    }
}
