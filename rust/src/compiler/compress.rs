//! Sparsity-aware CAM capacity compression (ROADMAP item 1; DESIGN.md §5
//! contract 11; ADR-008. Direction: MonoSparse-CAM 2407.11071, RETENTION
//! 2506.05994).
//!
//! Real tree ensembles are wildly sparse: a depth-d root-to-leaf path
//! constrains at most d of the model's features, so most macro-cells in a
//! compiled core are don't-care wildcards. This pass exploits that to cut
//! the *physical* CAM capacity a program occupies without touching its
//! *logical* contents:
//!
//! 1. **Shared-prefix merging** — two adjacent leaves of the same tree
//!    whose windows agree on every feature except the final split (where
//!    they are complementary halves: `hi_left == lo_right`) collapse into
//!    one physical word holding the union window, plus one *residual*
//!    macro-cell that re-applies the split threshold to pick the leaf.
//!    2 words → 1 word + 1 cell.
//! 2. **Don't-care-aware row packing** — units (single rows or merged
//!    pairs) whose constrained-feature sets are pairwise disjoint share
//!    one physical word: each cell is owned by at most one unit, the
//!    word image is the union of the owners' windows, and per-unit match
//!    lines sense only the owned segments (MonoSparse-CAM's scheme).
//! 3. **Arena interval dedup** — at engine lowering, elementary intervals
//!    whose membership bitsets are identical share one slice of the
//!    `CorePlan` arena through a slot indirection table (see
//!    `engine::CorePlan`). Fewer distinct slices = fewer words ANDed
//!    resident in cache.
//!
//! **Bit-identity by construction (contract 11):** the pass never
//! rewrites, reorders, or drops a logical row — it only *annotates* the
//! program with a [`CoreLayout`] describing how logical rows map onto
//! physical words. The functional engine keeps evaluating logical rows in
//! their original order, so predictions, f32 logits, f64 partial sums,
//! `charged_rows`, and defect draws (which are keyed on logical rows) are
//! identical to the uncompressed program on every path and thread count.
//! Verifier rule V7 (deny) checks that the annotation is a faithful
//! physical image; the differential suite in `tests/compression.rs` pins
//! the bit-identity end to end.

use super::paths::CamRow;
use super::program::CamProgram;
use crate::util::Json;

/// One compression unit: a single logical row, or a merged pair of
/// adjacent sibling leaves (`rows.1 = Some`) sharing a physical word
/// with one residual cell on `split_feature`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Unit {
    /// Logical row index(es) of this unit, in core order.
    pub rows: (u32, Option<u32>),
    /// For merged pairs: the one feature where the two rows are
    /// complementary halves (`hi_left == lo_right`); the residual cell
    /// lives here.
    pub split_feature: Option<u16>,
}

impl Unit {
    pub fn is_merged(&self) -> bool {
        self.rows.1.is_some()
    }
}

/// Physical image of one CAM word after packing: per-feature union
/// window plus the owning unit of every cell (`-1` = unowned wildcard).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WordImage {
    pub lo: Vec<u16>,
    pub hi: Vec<u16>,
    /// Unit index owning each cell, `-1` where no unit constrains it.
    pub owner: Vec<i32>,
}

/// Physical layout of one core: how its logical rows map onto physical
/// words. Purely an annotation — the logical rows stay authoritative for
/// inference (contract 11).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CoreLayout {
    /// `units[unit_of_row[r]]` covers logical row `r`.
    pub unit_of_row: Vec<u32>,
    pub units: Vec<Unit>,
    /// Physical word index of each unit.
    pub word_of_unit: Vec<u32>,
    /// Physical word images, `words.len()` = compressed capacity.
    pub words: Vec<WordImage>,
}

impl CoreLayout {
    /// Physical words this core occupies after compression.
    pub fn n_phys_rows(&self) -> usize {
        self.words.len()
    }

    /// Union window of a unit on one feature, recomputed from the
    /// logical rows (the ground truth V7 checks word images against).
    pub fn unit_window(&self, u: usize, rows: &[CamRow], f: usize) -> (u16, u16) {
        let (a, b) = self.units[u].rows;
        let lo = rows[a as usize].lo[f];
        let hi = match b {
            Some(b) => rows[b as usize].hi[f],
            None => rows[a as usize].hi[f],
        };
        (lo, hi)
    }

    /// Features a unit physically occupies: every feature where its
    /// union window is narrower than don't-care, plus the residual
    /// cell's split feature for merged pairs.
    pub fn unit_constrained(&self, u: usize, rows: &[CamRow], n_bins: u16) -> Vec<usize> {
        let n_features = rows[self.units[u].rows.0 as usize].lo.len();
        (0..n_features)
            .filter(|&f| {
                let (lo, hi) = self.unit_window(u, rows, f);
                lo != 0 || hi < n_bins || self.units[u].split_feature == Some(f as u16)
            })
            .collect()
    }

    // ---- canonical serialization (artifact store digests these bytes) --

    pub fn to_json(&self) -> Json {
        let units = self
            .units
            .iter()
            .map(|u| {
                Json::Arr(vec![
                    Json::Num(u.rows.0 as f64),
                    Json::Num(u.rows.1.map_or(-1.0, |r| r as f64)),
                    Json::Num(u.split_feature.map_or(-1.0, |f| f as f64)),
                ])
            })
            .collect();
        let words = self
            .words
            .iter()
            .map(|w| {
                let mut o = Json::obj();
                o.set("lo", Json::Arr(w.lo.iter().map(|&v| Json::Num(v as f64)).collect()))
                    .set("hi", Json::Arr(w.hi.iter().map(|&v| Json::Num(v as f64)).collect()))
                    .set(
                        "owner",
                        Json::Arr(w.owner.iter().map(|&v| Json::Num(v as f64)).collect()),
                    );
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("units", Json::Arr(units))
            .set(
                "word_of_unit",
                Json::Arr(self.word_of_unit.iter().map(|&w| Json::Num(w as f64)).collect()),
            )
            .set("words", Json::Arr(words));
        o
    }

    /// Decode one core's layout. `n_rows`/`n_features` come from the
    /// already-decoded core so a corrupt file surfaces as a structured
    /// error, never a slice panic downstream.
    pub fn from_json(j: &Json, ci: usize, n_rows: usize, n_features: usize) -> Result<CoreLayout, String> {
        let mut units = Vec::new();
        let mut unit_of_row = vec![u32::MAX; n_rows];
        for (ui, uj) in j.req_arr("units")?.iter().enumerate() {
            let t = uj.as_arr().ok_or_else(|| format!("core {ci}: layout unit {ui} is not an array"))?;
            if t.len() != 3 {
                return Err(format!("core {ci}: layout unit {ui} has {} fields, want 3", t.len()));
            }
            let num = |k: usize| -> Result<i64, String> {
                t[k].as_f64()
                    .map(|v| v as i64)
                    .ok_or_else(|| format!("core {ci}: layout unit {ui}[{k}] is not a number"))
            };
            let (r0, r1, sf) = (num(0)?, num(1)?, num(2)?);
            if r0 < 0 || r0 as usize >= n_rows || (r1 >= 0 && r1 as usize >= n_rows) {
                return Err(format!(
                    "core {ci}: layout unit {ui} references rows ({r0}, {r1}) outside 0..{n_rows}"
                ));
            }
            for r in [Some(r0), (r1 >= 0).then_some(r1)].into_iter().flatten() {
                if unit_of_row[r as usize] != u32::MAX {
                    return Err(format!("core {ci}: layout row {r} claimed by two units"));
                }
                unit_of_row[r as usize] = ui as u32;
            }
            if (r1 >= 0) != (sf >= 0) {
                return Err(format!(
                    "core {ci}: layout unit {ui}: merged pairs need a split feature (rows {r0},{r1}, split {sf})"
                ));
            }
            if sf >= n_features as i64 {
                return Err(format!("core {ci}: layout unit {ui} split feature {sf} ≥ {n_features}"));
            }
            units.push(Unit {
                rows: (r0 as u32, (r1 >= 0).then_some(r1 as u32)),
                split_feature: (sf >= 0).then_some(sf as u16),
            });
        }
        if let Some(r) = unit_of_row.iter().position(|&u| u == u32::MAX) {
            return Err(format!("core {ci}: layout covers no unit for row {r}"));
        }
        let word_of_unit: Vec<u32> =
            j.req("word_of_unit")?.usize_vec()?.into_iter().map(|w| w as u32).collect();
        if word_of_unit.len() != units.len() {
            return Err(format!(
                "core {ci}: layout has {} units but {} word assignments",
                units.len(),
                word_of_unit.len()
            ));
        }
        let mut words = Vec::new();
        for (wi, wj) in j.req_arr("words")?.iter().enumerate() {
            let lo: Vec<u16> =
                wj.req("lo")?.usize_vec()?.into_iter().map(|v| v as u16).collect();
            let hi: Vec<u16> =
                wj.req("hi")?.usize_vec()?.into_iter().map(|v| v as u16).collect();
            let owner: Vec<i32> = wj
                .req("owner")?
                .f64_vec()?
                .into_iter()
                .map(|v| v as i32)
                .collect();
            if lo.len() != n_features || hi.len() != n_features || owner.len() != n_features {
                return Err(format!(
                    "core {ci}: layout word {wi} arrays disagree (lo {}, hi {}, owner {} for {n_features} features)",
                    lo.len(),
                    hi.len(),
                    owner.len()
                ));
            }
            words.push(WordImage { lo, hi, owner });
        }
        for (u, &w) in word_of_unit.iter().enumerate() {
            if w as usize >= words.len() {
                return Err(format!(
                    "core {ci}: layout unit {u} mapped to word {w} ≥ {} words",
                    words.len()
                ));
            }
        }
        Ok(CoreLayout { unit_of_row, units, word_of_unit, words })
    }
}

/// What the pass achieved, per program (summed over cores). Ratios > 1
/// mean the compressed form is smaller; `sim/cost.rs` consumes the
/// physical row counts for the Fig. 8 area/power deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CompressionReport {
    /// Logical CAM rows (= uncompressed physical words).
    pub rows_before: usize,
    /// Physical words after merging + packing.
    pub rows_after: usize,
    /// Adjacent sibling-leaf pairs collapsed (technique 1).
    pub merged_pairs: usize,
    /// Residual threshold cells added by merging (one per pair).
    pub residual_cells: usize,
    /// Units placed into a word already holding another unit (technique 2).
    pub packed_units: usize,
    /// Distinct elementary-interval bitset slices before / after dedup
    /// (technique 3; counted on the ideal, defect-free plan).
    pub arena_slices_before: usize,
    pub arena_slices_after: usize,
    /// u64 arena words before / after dedup.
    pub arena_words_before: usize,
    pub arena_words_after: usize,
}

impl CompressionReport {
    /// CAM row (word-line) reduction factor.
    pub fn row_reduction(&self) -> f64 {
        if self.rows_after == 0 {
            1.0
        } else {
            self.rows_before as f64 / self.rows_after as f64
        }
    }

    /// Bitset-arena word reduction factor.
    pub fn arena_reduction(&self) -> f64 {
        if self.arena_words_after == 0 {
            1.0
        } else {
            self.arena_words_before as f64 / self.arena_words_after as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("rows_before", Json::Num(self.rows_before as f64))
            .set("rows_after", Json::Num(self.rows_after as f64))
            .set("row_reduction", Json::Num(self.row_reduction()))
            .set("merged_pairs", Json::Num(self.merged_pairs as f64))
            .set("residual_cells", Json::Num(self.residual_cells as f64))
            .set("packed_units", Json::Num(self.packed_units as f64))
            .set("arena_slices_before", Json::Num(self.arena_slices_before as f64))
            .set("arena_slices_after", Json::Num(self.arena_slices_after as f64))
            .set("arena_words_before", Json::Num(self.arena_words_before as f64))
            .set("arena_words_after", Json::Num(self.arena_words_after as f64))
            .set("arena_reduction", Json::Num(self.arena_reduction()));
        o
    }

    pub fn render(&self) -> String {
        format!(
            "rows {} → {} ({:.2}×: {} pairs merged, {} units packed, {} residual cells); \
             arena {} → {} u64 words ({:.2}×, {} → {} slices)",
            self.rows_before,
            self.rows_after,
            self.row_reduction(),
            self.merged_pairs,
            self.packed_units,
            self.residual_cells,
            self.arena_words_before,
            self.arena_words_after,
            self.arena_reduction(),
            self.arena_slices_before,
            self.arena_slices_after,
        )
    }
}

/// Two adjacent rows of one tree merge iff their windows agree on every
/// feature except exactly one, where they are complementary halves
/// (`hi_left == lo_right` — the final split of two sibling leaves).
fn merge_feature(a: &CamRow, b: &CamRow) -> Option<u16> {
    if a.tree != b.tree {
        return None;
    }
    let mut split = None;
    for f in 0..a.lo.len() {
        if a.lo[f] == b.lo[f] && a.hi[f] == b.hi[f] {
            continue;
        }
        // Complementary halves: same outer window, touching at the split.
        if split.is_some() || a.lo[f] >= a.hi[f] || b.lo[f] >= b.hi[f] || a.hi[f] != b.lo[f] {
            return None;
        }
        split = Some(f as u16);
    }
    split
}

/// Compress one core's rows into a [`CoreLayout`]: greedy left-to-right
/// prefix merging, then first-fit disjoint-constrained packing. Returns
/// the layout plus (merged_pairs, packed_units) for the report.
pub fn compress_core(rows: &[CamRow], n_features: usize, n_bins: u16) -> (CoreLayout, usize, usize) {
    // 1. Merge adjacent sibling leaves (pairs only, greedy left-to-right;
    //    pairs-of-pairs would need a second residual level — ADR-008).
    let mut units: Vec<Unit> = Vec::new();
    let mut unit_of_row = vec![0u32; rows.len()];
    let mut r = 0usize;
    let mut merged_pairs = 0usize;
    while r < rows.len() {
        let unit = if r + 1 < rows.len() {
            merge_feature(&rows[r], &rows[r + 1])
                .map(|f| Unit { rows: (r as u32, Some((r + 1) as u32)), split_feature: Some(f) })
        } else {
            None
        };
        let u = units.len() as u32;
        match unit {
            Some(unit) => {
                unit_of_row[r] = u;
                unit_of_row[r + 1] = u;
                units.push(unit);
                merged_pairs += 1;
                r += 2;
            }
            None => {
                unit_of_row[r] = u;
                units.push(Unit { rows: (r as u32, None), split_feature: None });
                r += 1;
            }
        }
    }

    // 2. First-fit packing: a unit joins the first word whose owned
    //    feature set is disjoint from its constrained set.
    let layout_probe = CoreLayout {
        unit_of_row: unit_of_row.clone(),
        units: units.clone(),
        word_of_unit: Vec::new(),
        words: Vec::new(),
    };
    let mut words: Vec<WordImage> = Vec::new();
    let mut word_of_unit = vec![0u32; units.len()];
    let mut packed_units = 0usize;
    for u in 0..units.len() {
        let constrained = layout_probe.unit_constrained(u, rows, n_bins);
        let fits = |w: &WordImage| constrained.iter().all(|&f| w.owner[f] < 0);
        let w = match words.iter().position(fits) {
            Some(w) => {
                packed_units += 1;
                w
            }
            None => {
                words.push(WordImage {
                    lo: vec![0; n_features],
                    hi: vec![n_bins; n_features],
                    owner: vec![-1; n_features],
                });
                words.len() - 1
            }
        };
        word_of_unit[u] = w as u32;
        for &f in &constrained {
            let (lo, hi) = layout_probe.unit_window(u, rows, f);
            words[w].lo[f] = lo;
            words[w].hi[f] = hi;
            words[w].owner[f] = u as i32;
        }
    }

    (CoreLayout { unit_of_row, units, word_of_unit, words }, merged_pairs, packed_units)
}

/// Arena dedup statistics for one core: (slices_before, slices_after,
/// bitset words per slice). Mirrors the membership construction in
/// `engine::CorePlan::build` on the ideal (defect-free) cells — bin
/// scaling is monotone, so the dedup classes are identical to what the
/// engine's lowering actually shares.
fn arena_stats(rows: &[CamRow], n_features: usize) -> (usize, usize, usize) {
    let n_words = rows.len().div_ceil(64).max(1);
    let mut before = 0usize;
    let mut unique: std::collections::HashSet<Vec<u64>> = std::collections::HashSet::new();
    for f in 0..n_features {
        let mut bounds: Vec<u16> = Vec::new();
        for row in rows {
            bounds.push(row.lo[f]);
            bounds.push(row.hi[f]);
        }
        bounds.retain(|&b| b > 0);
        bounds.sort_unstable();
        bounds.dedup();
        for i in 0..bounds.len() + 1 {
            let rep = if i == 0 { 0 } else { bounds[i - 1] };
            let mut slice = vec![0u64; n_words];
            for (r, row) in rows.iter().enumerate() {
                if row.lo[f] <= rep && rep < row.hi[f] {
                    slice[r / 64] |= 1u64 << (r % 64);
                }
            }
            before += 1;
            unique.insert(slice);
        }
    }
    (before, unique.len(), n_words)
}

/// Run the full compression pass over a compiled program: annotate every
/// core with its [`CoreLayout`] and return the [`CompressionReport`].
/// Logical rows are untouched (contract 11); callers opt in via
/// [`super::CompileOptions::compress`] or compress explicitly (the shard
/// partitioner recompresses each shard this way).
pub fn compress_program(program: &mut CamProgram) -> CompressionReport {
    let mut report = CompressionReport::default();
    let mut layouts = Vec::with_capacity(program.cores.len());
    for core in &program.cores {
        let (layout, merged, packed) = compress_core(&core.rows, program.n_features, program.n_bins);
        report.rows_before += core.rows.len();
        report.rows_after += layout.words.len();
        report.merged_pairs += merged;
        report.residual_cells += merged;
        report.packed_units += packed;
        let (s_before, s_after, n_words) = arena_stats(&core.rows, program.n_features);
        report.arena_slices_before += s_before;
        report.arena_slices_after += s_after;
        report.arena_words_before += s_before * n_words;
        report.arena_words_after += s_after * n_words;
        layouts.push(layout);
    }
    program.layouts = Some(layouts);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn row(lo: &[u16], hi: &[u16], tree: u32) -> CamRow {
        CamRow { lo: lo.to_vec(), hi: hi.to_vec(), leaf: 1.0, class: 0, tree }
    }

    #[test]
    fn sibling_leaves_merge() {
        // Two leaves split on feature 1 at bin 5: complementary halves.
        let a = row(&[2, 0, 0], &[7, 5, 16], 0);
        let b = row(&[2, 5, 0], &[7, 16, 16], 0);
        assert_eq!(merge_feature(&a, &b), Some(1));
        // Different trees never merge.
        let c = row(&[2, 5, 0], &[7, 16, 16], 1);
        assert_eq!(merge_feature(&a, &c), None);
        // A gap between the halves breaks the merge.
        let d = row(&[2, 6, 0], &[7, 16, 16], 0);
        assert_eq!(merge_feature(&a, &d), None);
        // Two differing features break it.
        let e = row(&[3, 5, 0], &[7, 16, 16], 0);
        assert_eq!(merge_feature(&a, &e), None);
    }

    #[test]
    fn disjoint_rows_pack_into_one_word() {
        // Three rows constraining disjoint features → one physical word.
        let rows = vec![
            row(&[1, 0, 0], &[4, 16, 16], 0),
            row(&[0, 2, 0], &[16, 9, 16], 1),
            row(&[0, 0, 3], &[16, 16, 8], 2),
        ];
        let (layout, merged, packed) = compress_core(&rows, 3, 16);
        assert_eq!(merged, 0);
        assert_eq!(packed, 2);
        assert_eq!(layout.words.len(), 1);
        let w = &layout.words[0];
        assert_eq!((w.lo[0], w.hi[0], w.owner[0]), (1, 4, 0));
        assert_eq!((w.lo[1], w.hi[1], w.owner[1]), (2, 9, 1));
        assert_eq!((w.lo[2], w.hi[2], w.owner[2]), (3, 8, 2));
    }

    #[test]
    fn conflicting_rows_stay_apart() {
        let rows = vec![row(&[1, 0], &[4, 16], 0), row(&[2, 0], &[9, 16], 1)];
        let (layout, _, packed) = compress_core(&rows, 2, 16);
        assert_eq!(packed, 0);
        assert_eq!(layout.words.len(), 2);
    }

    #[test]
    fn merged_pair_keeps_split_cell_owned() {
        // Siblings split on feature 0 whose union is full range: the
        // residual cell still claims the feature so another unit cannot
        // overwrite it.
        let rows = vec![
            row(&[0, 2], &[5, 9], 0),
            row(&[5, 2], &[16, 9], 0),
            row(&[3, 0], &[9, 16], 1),
        ];
        let (layout, merged, _) = compress_core(&rows, 2, 16);
        assert_eq!(merged, 1);
        assert_eq!(layout.units[0].split_feature, Some(0));
        // Unit 1 (row 2) constrains feature 0 → cannot share unit 0's word.
        assert_ne!(layout.word_of_unit[0], layout.word_of_unit[1]);
    }

    #[test]
    fn compress_trained_model_reduces_rows_and_roundtrips() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 10, max_leaves: 16, ..Default::default() },
            None,
        );
        let mut p = compile(&m, &CompileOptions::default()).unwrap();
        let rows_before = p.total_rows();
        let rep = compress_program(&mut p);
        assert_eq!(rep.rows_before, rows_before);
        assert!(rep.rows_after < rep.rows_before, "{}", rep.render());
        assert!(rep.arena_words_after <= rep.arena_words_before);
        let layouts = p.layouts.as_ref().unwrap();
        assert_eq!(layouts.len(), p.cores.len());
        // Layout invariants: every row covered exactly once, windows match.
        for (core, layout) in p.cores.iter().zip(layouts) {
            assert_eq!(layout.unit_of_row.len(), core.rows.len());
            for (u, unit) in layout.units.iter().enumerate() {
                assert_eq!(layout.unit_of_row[unit.rows.0 as usize], u as u32);
                if let Some(b) = unit.rows.1 {
                    assert_eq!(b, unit.rows.0 + 1, "merged rows must be adjacent");
                    assert_eq!(layout.unit_of_row[b as usize], u as u32);
                }
            }
            // JSON codec round-trips the layout exactly.
            let back = CoreLayout::from_json(
                &layout.to_json(),
                0,
                core.rows.len(),
                p.n_features,
            )
            .unwrap();
            assert_eq!(&back, layout);
        }
    }

    #[test]
    fn layout_decode_rejects_corruption() {
        let rows = vec![row(&[1, 0], &[4, 16], 0), row(&[0, 2], &[16, 9], 1)];
        let (layout, _, _) = compress_core(&rows, 2, 16);
        let good = layout.to_json();
        // Row index out of range.
        let mut j = good.clone();
        j.set("units", Json::Arr(vec![Json::Arr(vec![
            Json::Num(7.0),
            Json::Num(-1.0),
            Json::Num(-1.0),
        ])]));
        assert!(CoreLayout::from_json(&j, 3, 2, 2).unwrap_err().contains("core 3"));
        // Word assignment count mismatch.
        let mut j = good.clone();
        j.set("word_of_unit", Json::Arr(vec![Json::Num(0.0)]));
        assert!(CoreLayout::from_json(&j, 0, 2, 2).unwrap_err().contains("word assignments"));
        // Word arrays of the wrong arity.
        let mut j = good.clone();
        let mut w0 = Json::obj();
        w0.set("lo", Json::Arr(vec![Json::Num(0.0)]))
            .set("hi", Json::Arr(vec![Json::Num(16.0)]))
            .set("owner", Json::Arr(vec![Json::Num(-1.0)]));
        j.set("words", Json::Arr(vec![w0]));
        assert!(CoreLayout::from_json(&j, 0, 2, 2).unwrap_err().contains("arrays disagree"));
    }
}
