//! Functional CAM inference engine.
//!
//! Executes a [`CamProgram`] with the analog-CAM functional model:
//! per-core gated search (stacked/queued arrays), MMR match resolution,
//! SRAM leaf retrieval, in-core accumulation and in-network reduction.
//! Supports analog defect injection (Fig. 9b). This is the bit-accurate
//! reference the cycle simulator and the XLA backend are validated
//! against; absent defects it must agree with [`Ensemble::logits`]
//! (`trees` module) exactly up to summation order.
//!
//! Two query paths share the same semantics:
//!
//! * the **scalar path** ([`CamEngine::partials_bins`]) walks every CAM
//!   cell per query — the literal hardware model, retained as the
//!   defect-injection reference;
//! * the **batched path** ([`CamEngine::partials_batch`]) answers whole
//!   batches through a per-core, feature-major interval index built at
//!   engine construction: each feature column's distinct bound levels
//!   partition the 8-bit query space into elementary intervals whose
//!   matching row set is precomputed as u64 bitset words, so a query
//!   costs one binary search + a word-wide AND per feature instead of a
//!   per-cell scan. The batched path is bit-identical to the scalar path
//!   (same f64 accumulation order, same MMR truncation, same
//!   [`SearchStats`] counts) — property-tested in
//!   `rust/tests/batch_agreement.rs`.

use super::program::{compile, CamProgram, CompileError, CompileOptions};
use crate::cam::{
    inject_memristor_defects_tracked, CoreCam, DacErrors, DefectSpec, MacroCell, ARRAY_COLS,
};
use crate::data::{Dataset, Task};
use crate::trees::hat::{defect_aware_retrain, HatParams, RetrainReport};
use crate::trees::{metrics, Ensemble};
use crate::util::Rng;

/// Interval index of one feature column: the column's distinct bound
/// levels split the query space into elementary intervals on which the
/// set of matching rows is constant.
struct FeatureIndex {
    /// Ascending distinct non-zero bound levels. Elementary interval `i`
    /// spans `[bounds[i-1], bounds[i])`; interval 0 starts at level 0 and
    /// the last interval is unbounded above.
    bounds: Vec<u16>,
    /// `bounds.len() + 1` row bitsets of `n_words` words each,
    /// concatenated in interval order.
    words: Vec<u64>,
}

/// Feature-major interval index over one core's programmed (possibly
/// defect-perturbed) cells — the batched query path.
struct BatchIndex {
    n_words: usize,
    features: Vec<FeatureIndex>,
    /// All-rows mask (the last word is partially filled).
    full: Vec<u64>,
}

impl BatchIndex {
    /// Build from a row-major `[n_rows × n_features]` cell matrix. Must
    /// be built *after* defect injection so batched queries see the same
    /// programmed levels as the scalar path.
    fn build(n_rows: usize, n_features: usize, cells: &[MacroCell]) -> BatchIndex {
        debug_assert_eq!(cells.len(), n_rows * n_features);
        let n_words = n_rows.div_ceil(64).max(1);
        let mut full = vec![u64::MAX; n_words];
        let spare = n_words * 64 - n_rows;
        if n_rows == 0 {
            full = vec![0; n_words];
        } else if spare > 0 {
            full[n_words - 1] = u64::MAX >> spare;
        }
        let mut features = Vec::with_capacity(n_features);
        for f in 0..n_features {
            let mut bounds: Vec<u16> = Vec::with_capacity(2 * n_rows);
            for r in 0..n_rows {
                let c = cells[r * n_features + f];
                bounds.push(c.lo);
                bounds.push(c.hi);
            }
            // Level 0 is the query floor: an interval boundary there is
            // vacuous (no query lies below it).
            bounds.retain(|&b| b > 0);
            bounds.sort_unstable();
            bounds.dedup();
            // Within an elementary interval no bound level is crossed, so
            // row membership is constant; evaluate it once at the
            // interval's lower endpoint.
            let mut words = vec![0u64; (bounds.len() + 1) * n_words];
            for (i, w) in words.chunks_mut(n_words).enumerate() {
                let rep = if i == 0 { 0 } else { bounds[i - 1] };
                for r in 0..n_rows {
                    if cells[r * n_features + f].matches_ideal(rep) {
                        w[r / 64] |= 1u64 << (r % 64);
                    }
                }
            }
            features.push(FeatureIndex { bounds, words });
        }
        BatchIndex { n_words, features, full }
    }

    /// Bitset of rows whose window on feature `f` contains query level `q`.
    #[inline]
    fn rows_matching(&self, f: usize, q: u16) -> &[u64] {
        let fi = &self.features[f];
        let iv = fi.bounds.partition_point(|&b| b <= q);
        &fi.words[iv * self.n_words..(iv + 1) * self.n_words]
    }
}

/// Per-core compiled search state.
struct EngineCore {
    cam: CoreCam,
    /// Batched-path index over the same programmed cells as `cam`.
    index: BatchIndex,
    /// Leaf payloads per row.
    leaf: Vec<f32>,
    class: Vec<u16>,
    /// MMR iteration budget (= N_trees,core).
    n_trees_core: usize,
    dac: DacErrors,
}

/// Functional engine over a compiled program.
pub struct CamEngine {
    pub task: Task,
    pub n_outputs: usize,
    base_score: Vec<f32>,
    cores: Vec<EngineCore>,
    n_features: usize,
    /// Bin-space → 8-bit macro-cell level scale (`256 / n_bins`).
    scale: u16,
}

/// The single rounding of the bit-identity contract (DESIGN.md §5):
/// `partial as f32 + base`, with missing trailing base entries treated
/// as 0. Shared by both engine query paths and the sharded dispatcher's
/// cross-shard aggregation so the arithmetic cannot drift between them.
pub fn apply_base(acc: &[f64], base: &[f32]) -> Vec<f32> {
    acc.iter()
        .zip(base.iter().chain(std::iter::repeat(&0.0)))
        .map(|(&a, &b)| a as f32 + b)
        .collect()
}

/// Statistics of one inference (feeds the energy model).
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    /// Charged match lines per queued segment, summed over cores.
    pub charged_rows: usize,
    /// Total matched rows (MMR iterations consumed).
    pub matches: usize,
}

impl CamEngine {
    /// Build a defect-free engine.
    pub fn new(program: &CamProgram) -> CamEngine {
        Self::with_defects(program, DefectSpec::NONE, 0)
    }

    /// Build an engine with analog defects drawn from `seed`.
    pub fn with_defects(program: &CamProgram, defects: DefectSpec, seed: u64) -> CamEngine {
        let mut rng = Rng::new(seed ^ 0xDEFEC7);
        let scale = (crate::cam::MACRO_BINS / program.n_bins.max(1)) as u16;
        let mut cores = Vec::with_capacity(program.cores.len());
        for (ci, c) in program.cores.iter().enumerate() {
            let n_rows = c.rows.len();
            let mut crng = rng.fork(ci as u64);
            let (cells, _, dac) = core_defect_draw(program, c, defects, scale, &mut crng);
            let index = BatchIndex::build(n_rows, program.n_features, &cells);
            cores.push(EngineCore {
                cam: CoreCam::from_cells(n_rows, program.n_features, cells),
                index,
                leaf: c.rows.iter().map(|r| r.leaf).collect(),
                class: c.rows.iter().map(|r| r.class).collect(),
                n_trees_core: c.n_trees_core(),
                dac,
            });
        }
        CamEngine {
            task: program.task,
            n_outputs: program.task.n_outputs(),
            base_score: program.base_score.clone(),
            cores,
            n_features: program.n_features,
            scale,
        }
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Inference over quantized bins; returns logits per output column.
    pub fn infer_bins(&self, bins: &[u16]) -> Vec<f32> {
        self.infer_bins_stats(bins).0
    }

    /// Inference + search statistics.
    pub fn infer_bins_stats(&self, bins: &[u16]) -> (Vec<f32>, SearchStats) {
        let (acc, stats) = self.partials_bins_stats(bins);
        (apply_base(&acc, &self.base_score), stats)
    }

    /// Base-free per-class partial sums in f64 — the shard-aggregation
    /// contract: summing each shard engine's `partials_bins` and then
    /// applying `base` exactly as [`CamEngine::infer_bins`] does
    /// (`partial as f32 + base`) reproduces the unsharded logits.
    pub fn partials_bins(&self, bins: &[u16]) -> Vec<f64> {
        self.partials_bins_stats(bins).0
    }

    fn partials_bins_stats(&self, bins: &[u16]) -> (Vec<f64>, SearchStats) {
        assert_eq!(bins.len(), self.n_features, "feature arity mismatch");
        // Queries are scaled into the same 8-bit level space as the
        // programmed bounds, modelling the DAC's full-scale mapping.
        let scaled: Vec<u16> = bins.iter().map(|&b| b * self.scale).collect();
        let mut acc = vec![0f64; self.n_outputs];
        let mut stats = SearchStats::default();
        for core in &self.cores {
            // DAC conversion (possibly defective) then gated CAM search.
            let q = core.dac.apply_row(&scaled);
            let res = core.cam.search(&q);
            stats.charged_rows += res.charged_rows.iter().sum::<usize>();
            // MMR: resolve matches one at a time, bounded by the
            // iteration budget (§III-A). Defects can produce more matches
            // than trees; the hardware stops after N_trees,core tokens.
            let mut taken = 0usize;
            for (row, &m) in res.matches.iter().enumerate() {
                if !m {
                    continue;
                }
                if taken >= core.n_trees_core {
                    break;
                }
                taken += 1;
                acc[core.class[row] as usize] += core.leaf[row] as f64;
            }
            stats.matches += taken;
        }
        (acc, stats)
    }

    /// Batched inference over quantized bins; logits per row.
    /// Bit-identical to mapping [`CamEngine::infer_bins`] over the batch.
    pub fn infer_batch(&self, batch: &[Vec<u16>]) -> Vec<Vec<f32>> {
        self.infer_batch_stats(batch).0
    }

    /// Batched inference + search statistics summed over the batch.
    pub fn infer_batch_stats(&self, batch: &[Vec<u16>]) -> (Vec<Vec<f32>>, SearchStats) {
        let (accs, stats) = self.partials_batch_stats(batch);
        let logits = accs.iter().map(|acc| apply_base(acc, &self.base_score)).collect();
        (logits, stats)
    }

    /// Batched base-free partial sums — the batched form of
    /// [`CamEngine::partials_bins`], bit-identical per row.
    pub fn partials_batch(&self, batch: &[Vec<u16>]) -> Vec<Vec<f64>> {
        self.partials_batch_stats(batch).0
    }

    /// The batched hot path: per core, intersect per-feature match sets
    /// from the interval index as u64 bitset words instead of scanning
    /// every cell per row. The queued-segment gating of
    /// [`CoreCam::search`] is reproduced by snapshotting the active-set
    /// population at each segment boundary (`charged_rows`), and MMR
    /// consumes set bits in ascending row order under the same
    /// `n_trees_core` budget — so partials, logits and [`SearchStats`]
    /// (summed over the batch) are bit-identical to the scalar path.
    pub fn partials_batch_stats(&self, batch: &[Vec<u16>]) -> (Vec<Vec<f64>>, SearchStats) {
        let mut acc = vec![vec![0f64; self.n_outputs]; batch.len()];
        let mut stats = SearchStats::default();
        if batch.is_empty() {
            return (acc, stats);
        }
        // Same DAC full-scale mapping as the scalar path.
        let scaled: Vec<Vec<u16>> = batch
            .iter()
            .map(|bins| {
                assert_eq!(bins.len(), self.n_features, "feature arity mismatch");
                bins.iter().map(|&b| b * self.scale).collect()
            })
            .collect();
        let n_segments = self.n_features.div_ceil(ARRAY_COLS).max(1);
        let mut active: Vec<u64> = Vec::new();
        // Cores outer, batch rows inner: one core's index stays cache-hot
        // across the whole batch, and each row still accumulates its
        // per-core contributions in core order (the scalar f64 order).
        for core in &self.cores {
            let idx = &core.index;
            for (q, row_acc) in scaled.iter().zip(acc.iter_mut()) {
                active.clear();
                active.extend_from_slice(&idx.full);
                for s in 0..n_segments {
                    // Queued gating: segment s charges the rows still
                    // active after the previous segments' features.
                    let live: usize = active.iter().map(|w| w.count_ones() as usize).sum();
                    stats.charged_rows += live;
                    let c0 = s * ARRAY_COLS;
                    let c1 = ((s + 1) * ARRAY_COLS).min(self.n_features);
                    for f in c0..c1 {
                        let m = idx.rows_matching(f, core.dac.apply(f, q[f]));
                        for (a, &w) in active.iter_mut().zip(m) {
                            *a &= w;
                        }
                    }
                    // Later segments would charge popcount(∅) = 0 rows.
                    if active.iter().all(|&w| w == 0) {
                        break;
                    }
                }
                // MMR over set bits in ascending row order, bounded by
                // the core's iteration budget — the scalar loop exactly.
                let mut taken = 0usize;
                'mmr: for (w, &word0) in active.iter().enumerate() {
                    let mut word = word0;
                    while word != 0 {
                        if taken >= core.n_trees_core {
                            break 'mmr;
                        }
                        let row = w * 64 + word.trailing_zeros() as usize;
                        taken += 1;
                        row_acc[core.class[row] as usize] += core.leaf[row] as f64;
                        word &= word - 1;
                    }
                }
                stats.matches += taken;
            }
        }
        (acc, stats)
    }

    /// Quantize a raw feature row with the program's quantizer, then infer.
    pub fn infer_row(&self, program: &CamProgram, row: &[f32]) -> Vec<f32> {
        let bins = program.quantizer.bin_row(row);
        self.infer_bins(&bins)
    }

    /// Task-level decision from logits (the co-processor's job, §III-A).
    pub fn decide(&self, logits: &[f32]) -> f32 {
        match self.task {
            Task::Regression => logits[0],
            Task::Binary => (logits[0] > 0.0) as usize as f32,
            Task::MultiClass(_) => {
                let mut best = 0usize;
                for c in 1..logits.len() {
                    if logits[c] > logits[best] {
                        best = c;
                    }
                }
                best as f32
            }
        }
    }

    /// End-to-end prediction for a raw row.
    pub fn predict(&self, program: &CamProgram, row: &[f32]) -> f32 {
        let l = self.infer_row(program, row);
        self.decide(&l)
    }
}

/// One core's defect draw: scaled cell image + perturbation + DAC error
/// table, consumed from `crng` in a single canonical order. This is the
/// **only** definition of the per-core defect stream — both
/// [`CamEngine::with_defects`] (which keeps the cells/DAC) and
/// [`defect_affected_trees`] (which keeps the changed-cell report) call
/// it, so the replay can never desynchronize from the engine.
fn core_defect_draw(
    program: &CamProgram,
    core: &super::program::CoreImage,
    defects: DefectSpec,
    scale: u16,
    crng: &mut Rng,
) -> (Vec<MacroCell>, Vec<usize>, DacErrors) {
    let mut cells = Vec::with_capacity(core.rows.len() * program.n_features);
    for r in &core.rows {
        for f in 0..program.n_features {
            // Bounds are scaled into the 8-bit macro-cell level space so
            // 4-bit programs exercise the same hardware path with coarser
            // levels.
            cells.push(MacroCell::new(r.lo[f] * scale, r.hi[f] * scale));
        }
    }
    let changed = inject_memristor_defects_tracked(&mut cells, defects.memristor_pct, crng);
    let dac = DacErrors::draw(program.n_features, defects.dac_pct, crng);
    (cells, changed, dac)
}

/// Tree ids whose CAM rows land on cells perturbed by the defect draw
/// `(defects, seed)` — replayed over the *identical* rng stream
/// [`CamEngine::with_defects`] consumes (shared `core_defect_draw`), so
/// the returned set is exactly the set of trees whose deployed rows
/// differ from their ideal programming in that engine. This is the
/// "known defect map" oracle of the defect-aware retrain loop
/// (`trees::hat::defect_aware_retrain`).
pub fn defect_affected_trees(program: &CamProgram, defects: DefectSpec, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed ^ 0xDEFEC7);
    let scale = (crate::cam::MACRO_BINS / program.n_bins.max(1)) as u16;
    let mut affected: Vec<u32> = Vec::new();
    for (ci, c) in program.cores.iter().enumerate() {
        let mut crng = rng.fork(ci as u64);
        let (_, changed, _) = core_defect_draw(program, c, defects, scale, &mut crng);
        for idx in changed {
            affected.push(c.rows[idx / program.n_features].tree);
        }
    }
    affected.sort_unstable();
    affected.dedup();
    affected
}

/// Task score (accuracy, or R² for regression) of `program` served
/// through a *defective* engine — the deployment-side objective the
/// defect-aware retrain loop maximizes. Rows go through the batched
/// interval-index path (bit-identical to the scalar path, contract 4),
/// which is what makes per-pass probing over a large eval set cheap.
pub fn defective_score(
    program: &CamProgram,
    defects: DefectSpec,
    seed: u64,
    data: &Dataset,
) -> f64 {
    let engine = CamEngine::with_defects(program, defects, seed);
    let batch: Vec<Vec<u16>> =
        (0..data.n_rows()).map(|i| program.quantizer.bin_row(data.row(i))).collect();
    let preds: Vec<f32> =
        engine.infer_batch(&batch).iter().map(|logits| engine.decide(logits)).collect();
    match data.task {
        Task::Regression => metrics::r2(&preds, &data.y),
        _ => metrics::accuracy(&preds, &data.y),
    }
}

/// Pre-wired defect-aware HAT retraining: compiles each candidate model
/// with `options`, identifies the trees whose rows land on the chip's
/// known defect draw `(defects, seed)` and re-fits them
/// ([`crate::trees::hat::refit_trees`]), keeping the pass that scores
/// best on `eval` through the defective engine. An input model that does
/// not compile is an `Err`; mid-loop compile failures of *retrained*
/// candidates score `-inf` so an earlier pass wins instead of
/// panicking. Exactly one compile per probe (= per retrain pass, plus
/// one for the input model).
pub fn hat_defect_retrain(
    train: &Dataset,
    eval: &Dataset,
    model: Ensemble,
    params: &HatParams,
    options: &CompileOptions,
    defects: DefectSpec,
    seed: u64,
) -> Result<(Ensemble, RetrainReport), CompileError> {
    // The input model's compile error (if any) surfaces from its own
    // probe — no separate validation compile.
    let first_compile_error: std::cell::RefCell<Option<CompileError>> =
        std::cell::RefCell::new(None);
    let probe = |m: &Ensemble| match compile(m, options) {
        Ok(p) => {
            (defect_affected_trees(&p, defects, seed), defective_score(&p, defects, seed, eval))
        }
        Err(e) => {
            let mut slot = first_compile_error.borrow_mut();
            if slot.is_none() {
                *slot = Some(e);
            }
            (Vec::new(), f64::NEG_INFINITY)
        }
    };
    let (best, report) = defect_aware_retrain(train, model, params, &probe);
    // The first probe is always the input model; if *it* failed to
    // compile, the loop never ran (empty affected set ⇒ zero passes) and
    // the stashed error is the input's. With passes > 0 the input
    // compiled, and any stashed error came from a discarded retrain
    // candidate — already handled by its -inf score.
    if report.passes == 0 {
        if let Some(e) = first_compile_error.borrow_mut().take() {
            return Err(e);
        }
    }
    Ok((best, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::program::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, rf, GbdtParams, RfParams};

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn engine_matches_cpu_reference_binary() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 15, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        for i in 0..200 {
            let row = d.row(i);
            let cam = e.infer_row(&p, row);
            let cpu = m.logits(row);
            assert!(close(cam[0], cpu[0]), "row {i}: cam {} vs cpu {}", cam[0], cpu[0]);
            assert_eq!(e.predict(&p, row), m.predict(row));
        }
    }

    #[test]
    fn engine_matches_cpu_reference_multiclass() {
        let d = by_name("eye").unwrap().generate_n(1200);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
            None,
        );
        // Force a multi-core layout to exercise placement + reduction.
        let p = compile(&m, &CompileOptions { core_rows: 48, ..Default::default() }).unwrap();
        assert!(p.cores_per_replica() > 1);
        let e = CamEngine::new(&p);
        for i in 0..150 {
            let row = d.row(i);
            let cam = e.infer_row(&p, row);
            let cpu = m.logits(row);
            for k in 0..cam.len() {
                assert!(close(cam[k], cpu[k]), "row {i} class {k}: {} vs {}", cam[k], cpu[k]);
            }
        }
    }

    #[test]
    fn engine_matches_cpu_reference_rf_regression() {
        let d = by_name("rossmann").unwrap().generate_n(1000);
        let m = rf::train(&d, &RfParams { n_estimators: 10, max_leaves: 32, ..Default::default() });
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        for i in 0..100 {
            let row = d.row(i);
            assert!(close(e.infer_row(&p, row)[0], m.logits(row)[0]), "row {i}");
        }
    }

    #[test]
    fn four_bit_program_runs_on_macro_cells() {
        let d = by_name("telco").unwrap().generate_n(900);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, n_bits: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_bins, 16);
        let e = CamEngine::new(&p);
        for i in 0..100 {
            let row = d.row(i);
            assert!(close(e.infer_row(&p, row)[0], m.logits(row)[0]), "row {i}");
        }
    }

    #[test]
    fn defects_degrade_gracefully() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 20, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let clean = CamEngine::new(&p);
        let dirty = CamEngine::with_defects(&p, DefectSpec::memristor(0.3), 42);
        let mut clean_hits = 0;
        let mut dirty_hits = 0;
        let n = 400;
        for i in 0..n {
            let row = d.row(i);
            clean_hits += (clean.predict(&p, row) == d.y[i]) as usize;
            dirty_hits += (dirty.predict(&p, row) == d.y[i]) as usize;
        }
        let (ca, da) = (clean_hits as f64 / n as f64, dirty_hits as f64 / n as f64);
        // Heavy defects must hurt but the ensemble keeps it above chance.
        assert!(da <= ca + 0.02, "defects improved accuracy? {ca} vs {da}");
        assert!(da > 0.5, "catastrophic collapse: {da}");
    }

    #[test]
    fn small_defect_rate_nearly_harmless() {
        // Paper: ~0.2% flip probability → accuracy drop < 0.5%.
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 20, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let clean = CamEngine::new(&p);
        let dirty = CamEngine::with_defects(&p, DefectSpec::memristor(0.002), 7);
        let n = 400;
        let mut agree = 0;
        for i in 0..n {
            let row = d.row(i);
            agree += (clean.predict(&p, row) == dirty.predict(&p, row)) as usize;
        }
        assert!(agree as f64 / n as f64 > 0.97, "agreement {}", agree as f64 / n as f64);
    }

    /// Cheap in-module smoke of the batched/scalar bit-identity contract
    /// (the exhaustive property suite — tasks × precisions × defects ×
    /// shard plans — lives in `rust/tests/batch_agreement.rs`).
    #[test]
    fn batched_path_smoke_bit_identical() {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        let batch: Vec<Vec<u16>> = (0..32).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        let (partials, stats) = e.partials_batch_stats(&batch);
        let logits = e.infer_batch(&batch);
        let (mut charged, mut matches) = (0usize, 0usize);
        for (i, bins) in batch.iter().enumerate() {
            assert_eq!(partials[i], e.partials_bins(bins), "row {i} partials");
            let (want, s) = e.infer_bins_stats(bins);
            assert_eq!(logits[i], want, "row {i} logits");
            charged += s.charged_rows;
            matches += s.matches;
        }
        assert_eq!(stats.charged_rows, charged, "charged_rows drifted");
        assert_eq!(stats.matches, matches, "matches drifted");
        // Empty batches are a no-op, not a panic.
        let (empty, zero) = e.partials_batch_stats(&[]);
        assert!(empty.is_empty());
        assert_eq!((zero.charged_rows, zero.matches), (0, 0));
    }

    #[test]
    fn defect_affected_trees_replays_the_engine_draw() {
        let d = by_name("churn").unwrap().generate_n(900);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        // No defects → nothing affected.
        assert!(defect_affected_trees(&p, DefectSpec::NONE, 3).is_empty());
        // Saturated defects → (essentially) every tree affected.
        let all = defect_affected_trees(&p, DefectSpec::memristor(1.0), 3);
        assert_eq!(all.len(), p.n_trees, "pct=1 must touch every tree");
        assert!(all.iter().all(|&t| (t as usize) < p.n_trees));
        // Deterministic replay.
        let a = defect_affected_trees(&p, DefectSpec::memristor(0.05), 11);
        let b = defect_affected_trees(&p, DefectSpec::memristor(0.05), 11);
        assert_eq!(a, b);
        // When the replay says "no tree affected", the defective engine
        // must be bit-identical to the clean one (the whole point of
        // replaying the engine's exact rng stream).
        let clean = CamEngine::new(&p);
        let spec = DefectSpec::memristor(0.001);
        let mut verified = false;
        for seed in 0..64u64 {
            if !defect_affected_trees(&p, spec, seed).is_empty() {
                continue;
            }
            let dirty = CamEngine::with_defects(&p, spec, seed);
            for i in 0..100 {
                let bins = p.quantizer.bin_row(d.row(i));
                assert_eq!(clean.infer_bins(&bins), dirty.infer_bins(&bins), "seed {seed} row {i}");
            }
            verified = true;
            break;
        }
        assert!(verified, "no defect-free draw found in 64 seeds — shrink the program");
    }

    #[test]
    fn defective_score_matches_clean_engine_without_defects() {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let s = defective_score(&p, DefectSpec::NONE, 0, &d);
        assert!((0.0..=1.0).contains(&s));
        let e = CamEngine::new(&p);
        let mut hits = 0usize;
        for i in 0..d.n_rows() {
            hits += (e.predict(&p, d.row(i)) == d.y[i]) as usize;
        }
        assert!((s - hits as f64 / d.n_rows() as f64).abs() < 1e-12);
    }

    #[test]
    fn hat_defect_retrain_end_to_end_never_degrades() {
        use crate::trees::hat::{self, HatParams};
        let d = by_name("churn").unwrap().generate_n(1500);
        let split = d.split(0.7, 0.0, 23);
        let params = HatParams {
            deploy_bits: 4,
            gbdt: GbdtParams { n_rounds: 10, max_leaves: 8, ..Default::default() },
            retrain_passes: 2,
            ..Default::default()
        };
        let model = hat::train(&split.train, &params, None);
        let spec = DefectSpec::memristor(0.1);
        let (better, report) = hat_defect_retrain(
            &split.train,
            &split.test,
            model,
            &params,
            &CompileOptions::default(),
            spec,
            7,
        )
        .unwrap();
        assert!(report.passes <= 2);
        assert!(
            report.final_score >= report.initial_score,
            "retrain degraded the deployed score: {report:?}"
        );
        // The returned model still compiles and deploys losslessly.
        let (_, hat_report) =
            crate::compiler::program::compile_for_deploy(&better, 4, &CompileOptions::default())
                .unwrap();
        hat_report.assert_lossless("retrained model");
    }

    #[test]
    fn stats_report_charged_rows() {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        let bins = p.quantizer.bin_row(d.row(0));
        let (_, stats) = e.infer_bins_stats(&bins);
        // Exactly one row matches per tree.
        assert_eq!(stats.matches, 4);
        assert!(stats.charged_rows >= p.total_rows());
    }
}
