//! Functional CAM inference engine.
//!
//! Executes a [`CamProgram`] with the analog-CAM functional model:
//! per-core gated search (stacked/queued arrays), MMR match resolution,
//! SRAM leaf retrieval, in-core accumulation and in-network reduction.
//! Supports analog defect injection (Fig. 9b). This is the bit-accurate
//! reference the cycle simulator and the XLA backend are validated
//! against; absent defects it must agree with [`Ensemble::logits`]
//! (`trees` module) exactly up to summation order.
//!
//! Three query paths share the same semantics:
//!
//! * the **scalar path** ([`CamEngine::partials_bins`]) walks every CAM
//!   cell per query — the literal hardware model, retained as the
//!   defect-injection reference;
//! * the **indexed path** ([`CamEngine::partials_batch`]) answers whole
//!   batches through the per-core [`CorePlan`]'s interval bounds: each
//!   feature column's distinct bound levels partition the 8-bit query
//!   space into elementary intervals whose matching row set is
//!   precomputed as u64 bitset words, so a query costs one binary search
//!   + a word-wide AND per feature instead of a per-cell scan;
//! * the **planned path** ([`CamEngine::partials_planned`]) executes the
//!   same [`CorePlan`] flat-out: the binary search becomes one load from
//!   a per-feature 256-entry level→interval LUT (the DAC space is
//!   8-bit), interval bitsets live in a single per-core arena (one
//!   allocation, offset-addressed, cache-local), traversal is
//!   query-blocked (a block of rows ANDs against the same feature's
//!   match words before moving on), and cores can be partitioned across
//!   a deterministic thread pool.
//!
//! All three are bit-identical — same f64 accumulation order, same MMR
//! truncation, same [`SearchStats`] counts — for every thread count
//! (property-tested in `rust/tests/batch_agreement.rs`; determinism
//! contract in `docs/adr/002-planned-execution.md`).

use super::program::{compile, CamProgram, CompileError, CompileOptions};
use crate::cam::{
    dac_level, inject_memristor_defects_tracked, CoreCam, DacErrors, DefectSpec, MacroCell,
    ARRAY_COLS, MACRO_BINS,
};
use crate::data::{Dataset, Task};
use crate::trees::hat::{defect_aware_retrain, HatParams, RetrainReport};
use crate::trees::{metrics, Ensemble};
use crate::util::Rng;

/// Rows the planned path traverses together before moving to the next
/// feature: all rows of a block reuse the feature's (cache-hot) interval
/// slices in the arena.
const QUERY_BLOCK: usize = 8;

/// Per-feature view into a [`CorePlan`]: the ascending distinct non-zero
/// bound levels (the indexed path's binary-search key; elementary
/// interval `i` spans `[bounds[i-1], bounds[i])`, interval 0 starts at
/// level 0 and the last interval is unbounded above) plus the word
/// offset of this feature's interval slices in the core's shared arena.
struct PlanFeature {
    bounds: Vec<u16>,
    /// Position of interval 0: a word offset into [`CorePlan::arena`]
    /// for direct plans, or this feature's base index into
    /// [`CorePlan::slots`] for deduplicated plans (compressed programs).
    off: usize,
}

/// Compiled execution plan of one core's programmed (possibly
/// defect-perturbed) cells — the flat data structure both batched query
/// paths run on:
///
/// * `lut` — per feature, a 256-entry level→interval-id table (one entry
///   per 8-bit DAC level), making interval resolution a single array
///   load on the planned path;
/// * `arena` — one contiguous allocation holding every feature's
///   interval row-bitsets back to back (`PlanFeature::off` addresses a
///   feature's slice), replacing per-feature `Vec<u64>`s.
struct CorePlan {
    n_words: usize,
    features: Vec<PlanFeature>,
    /// Flattened `[n_features × 256]` level→interval-id lookup table.
    lut: Vec<u16>,
    /// Interval bitsets, `n_words` words each. Direct plans store one
    /// slice per (feature, interval) back to back; deduplicated plans
    /// store only *distinct* slices, indirected through `slots`.
    arena: Vec<u64>,
    /// All-rows mask (the last word is partially filled).
    full: Vec<u64>,
    /// Compression technique 3 (contract 11): per (feature, interval),
    /// the arena slice index holding its membership bitset — identical
    /// elementary intervals across all features of the core share one
    /// slice. `None` = direct (uncompressed) addressing.
    slots: Option<Vec<u32>>,
}

impl CorePlan {
    /// Build from a row-major `[n_rows × n_features]` cell matrix. Must
    /// be built *after* defect injection so batched queries see the same
    /// programmed levels as the scalar path.
    ///
    /// With `dedup` (compressed programs, contract 11), elementary
    /// intervals whose membership bitsets are identical — across *all*
    /// features of the core — share one arena slice through the `slots`
    /// indirection. The slices any query resolves to are bit-for-bit the
    /// ones the direct plan would return, so both addressing modes are
    /// interchangeable on every path.
    fn build(n_rows: usize, n_features: usize, cells: &[MacroCell], dedup: bool) -> CorePlan {
        debug_assert_eq!(cells.len(), n_rows * n_features);
        let n_words = n_rows.div_ceil(64).max(1);
        let mut full = vec![u64::MAX; n_words];
        let spare = n_words * 64 - n_rows;
        if n_rows == 0 {
            full = vec![0; n_words];
        } else if spare > 0 {
            full[n_words - 1] = u64::MAX >> spare;
        }
        let mut features = Vec::with_capacity(n_features);
        let mut lut = vec![0u16; n_features * MACRO_BINS as usize];
        let mut arena: Vec<u64> = Vec::new();
        let mut slots: Vec<u32> = Vec::new();
        let mut seen: std::collections::HashMap<Vec<u64>, u32> = std::collections::HashMap::new();
        for f in 0..n_features {
            let mut bounds: Vec<u16> = Vec::with_capacity(2 * n_rows);
            for r in 0..n_rows {
                let c = cells[r * n_features + f];
                bounds.push(c.lo);
                bounds.push(c.hi);
            }
            // Level 0 is the query floor: an interval boundary there is
            // vacuous (no query lies below it).
            bounds.retain(|&b| b > 0);
            bounds.sort_unstable();
            bounds.dedup();
            // Within an elementary interval no bound level is crossed, so
            // row membership is constant; evaluate it once at the
            // interval's lower endpoint.
            let off = if dedup { slots.len() } else { arena.len() };
            if !dedup {
                arena.resize(off + (bounds.len() + 1) * n_words, 0);
            }
            for i in 0..=bounds.len() {
                let rep = if i == 0 { 0 } else { bounds[i - 1] };
                let mut slice = vec![0u64; n_words];
                for r in 0..n_rows {
                    if cells[r * n_features + f].matches_ideal(rep) {
                        slice[r / 64] |= 1u64 << (r % 64);
                    }
                }
                if dedup {
                    let next = (arena.len() / n_words) as u32;
                    let slot = *seen.entry(slice.clone()).or_insert_with(|| {
                        arena.extend_from_slice(&slice);
                        next
                    });
                    slots.push(slot);
                } else {
                    arena[off + i * n_words..off + (i + 1) * n_words].copy_from_slice(&slice);
                }
            }
            // LUT sweep: interval id = number of bounds ≤ level, i.e. the
            // same value `partition_point` computes, tabulated for every
            // 8-bit DAC level in one O(256 + |bounds|) pass. Bounds above
            // 255 (a `hi` of 256) are never ≤ a DAC level and simply stay
            // ahead of the sweep.
            let table = &mut lut[f * MACRO_BINS as usize..(f + 1) * MACRO_BINS as usize];
            let mut bi = 0usize;
            for (level, slot) in table.iter_mut().enumerate() {
                while bi < bounds.len() && (bounds[bi] as usize) <= level {
                    bi += 1;
                }
                *slot = bi as u16;
            }
            features.push(PlanFeature { bounds, off });
        }
        CorePlan { n_words, features, lut, arena, full, slots: dedup.then_some(slots) }
    }

    /// Resolve interval `iv` of feature `f` to its arena slice, through
    /// the slot table when deduplicated.
    #[inline]
    fn interval_slice(&self, f: usize, iv: usize) -> &[u64] {
        let off = self.features[f].off;
        let start = match &self.slots {
            Some(slots) => slots[off + iv] as usize * self.n_words,
            None => off + iv * self.n_words,
        };
        &self.arena[start..][..self.n_words]
    }

    /// Planned-path interval resolution: one LUT load. `q` must already
    /// be a saturated 8-bit DAC level (guaranteed by [`dac_level`] /
    /// [`DacErrors::apply`], both of which clamp to 255).
    #[inline]
    fn rows_matching(&self, f: usize, q: u16) -> &[u64] {
        debug_assert!(q < MACRO_BINS, "query level {q} escaped DAC saturation");
        let iv = self.lut[f * MACRO_BINS as usize + q as usize] as usize;
        self.interval_slice(f, iv)
    }

    /// Indexed-path interval resolution: binary search over the bound
    /// levels (kept as the planned path's measured baseline).
    #[inline]
    fn rows_matching_indexed(&self, f: usize, q: u16) -> &[u64] {
        let fi = &self.features[f];
        let iv = fi.bounds.partition_point(|&b| b <= q);
        self.interval_slice(f, iv)
    }
}

/// Per-core compiled search state.
struct EngineCore {
    cam: CoreCam,
    /// Execution plan over the same programmed cells as `cam`.
    plan: CorePlan,
    /// Leaf payloads per row.
    leaf: Vec<f32>,
    class: Vec<u16>,
    /// MMR iteration budget (= N_trees,core).
    n_trees_core: usize,
    dac: DacErrors,
}

/// Functional engine over a compiled program.
pub struct CamEngine {
    pub task: Task,
    pub n_outputs: usize,
    base_score: Vec<f32>,
    cores: Vec<EngineCore>,
    n_features: usize,
    /// Bin-space → 8-bit macro-cell level scale (`256 / n_bins`).
    scale: u16,
}

/// Read-only per-core view for the static verifier (`analysis`
/// module): the programmed (possibly defect-perturbed) cells and the
/// [`CorePlan`]'s interval bounds, LUT, arena bitsets and masks.
/// Obtained via [`CamEngine::plan_view`]; exists so the verifier can
/// cross-check plan against cells without the plan internals becoming
/// public mutable surface.
pub struct PlanView<'a> {
    core: &'a EngineCore,
    n_features: usize,
}

impl PlanView<'_> {
    pub fn n_rows(&self) -> usize {
        self.core.leaf.len()
    }

    pub fn n_words(&self) -> usize {
        self.core.plan.n_words
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Ascending distinct non-zero bound levels of feature `f`
    /// (elementary interval `i` spans `[bounds[i-1], bounds[i])`).
    pub fn bounds(&self, f: usize) -> &[u16] {
        &self.core.plan.features[f].bounds
    }

    /// Position of feature `f`'s interval 0: an arena word offset for
    /// direct plans, a slot-table base index for deduplicated plans
    /// (see [`PlanView::slots`]).
    pub fn offset(&self, f: usize) -> usize {
        self.core.plan.features[f].off
    }

    pub fn arena(&self) -> &[u64] {
        &self.core.plan.arena
    }

    /// The (feature, interval) → arena-slice slot table of a
    /// deduplicated plan; `None` for direct plans.
    pub fn slots(&self) -> Option<&[u32]> {
        self.core.plan.slots.as_deref()
    }

    /// The membership bitset of feature `f`'s elementary interval `iv`,
    /// resolved through whichever addressing mode the plan uses — the
    /// verifier's probe for rule V7's match-set equivalence check.
    pub fn interval_slice(&self, f: usize, iv: usize) -> &[u64] {
        self.core.plan.interval_slice(f, iv)
    }

    /// The all-rows mask (last word partially filled).
    pub fn full_mask(&self) -> &[u64] {
        &self.core.plan.full
    }

    /// Level→interval LUT entry for feature `f` at DAC `level`.
    pub fn lut(&self, f: usize, level: usize) -> u16 {
        self.core.plan.lut[f * MACRO_BINS as usize + level]
    }

    /// The programmed macro-cell at row `r`, feature `f` (DAC space).
    pub fn cell(&self, r: usize, f: usize) -> MacroCell {
        *self.core.cam.segments[f / ARRAY_COLS].cell(r, f % ARRAY_COLS)
    }
}

/// The single rounding of the bit-identity contract (DESIGN.md §5):
/// `partial as f32 + base`, with missing trailing base entries treated
/// as 0. Shared by both engine query paths and the sharded dispatcher's
/// cross-shard aggregation so the arithmetic cannot drift between them.
pub fn apply_base(acc: &[f64], base: &[f32]) -> Vec<f32> {
    acc.iter()
        .zip(base.iter().chain(std::iter::repeat(&0.0)))
        .map(|(&a, &b)| a as f32 + b)
        .collect()
}

/// Statistics of one inference (feeds the energy model).
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    /// Charged match lines per queued segment, summed over cores.
    pub charged_rows: usize,
    /// Total matched rows (MMR iterations consumed).
    pub matches: usize,
}

impl CamEngine {
    /// Build a defect-free engine.
    pub fn new(program: &CamProgram) -> CamEngine {
        Self::with_defects(program, DefectSpec::NONE, 0)
    }

    /// Build an engine with analog defects drawn from `seed`.
    pub fn with_defects(program: &CamProgram, defects: DefectSpec, seed: u64) -> CamEngine {
        let mut rng = Rng::new(seed ^ 0xDEFEC7);
        let scale = (crate::cam::MACRO_BINS / program.n_bins.max(1)) as u16;
        // Compressed programs lower with the deduplicated arena
        // (compression technique 3). The defect draw below is keyed on
        // the *logical* rows, which compression never touches, so the
        // draw — and therefore every programmed cell — is identical to
        // the uncompressed engine's (contract 11).
        let dedup = program.layouts.is_some();
        let mut cores = Vec::with_capacity(program.cores.len());
        for (ci, c) in program.cores.iter().enumerate() {
            let n_rows = c.rows.len();
            let mut crng = rng.fork(ci as u64);
            let (cells, _, dac) = core_defect_draw(program, c, defects, scale, &mut crng);
            let plan = CorePlan::build(n_rows, program.n_features, &cells, dedup);
            cores.push(EngineCore {
                cam: CoreCam::from_cells(n_rows, program.n_features, cells),
                plan,
                leaf: c.rows.iter().map(|r| r.leaf).collect(),
                class: c.rows.iter().map(|r| r.class).collect(),
                n_trees_core: c.n_trees_core(),
                dac,
            });
        }
        CamEngine {
            task: program.task,
            n_outputs: program.task.n_outputs(),
            base_score: program.base_score.clone(),
            cores,
            n_features: program.n_features,
            scale,
        }
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Cores in the compiled program (one [`CorePlan`] each).
    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Read-only view of core `ci`'s compiled state — programmed cells
    /// plus the plan's bounds/LUT/arena — for the static verifier
    /// (`analysis` module). Keeps [`CorePlan`] internals private while
    /// letting the verifier audit them against the cells.
    pub fn plan_view(&self, ci: usize) -> PlanView<'_> {
        PlanView { core: &self.cores[ci], n_features: self.n_features }
    }

    /// Mutation-test hook (`rust/tests/analysis.rs`): bump one LUT
    /// entry so level→interval resolution disagrees with the bounds —
    /// rule V1 must fire, and only V1 (the arena is untouched).
    #[doc(hidden)]
    pub fn corrupt_lut_entry(&mut self, ci: usize, f: usize, level: usize) {
        let i = f * MACRO_BINS as usize + level;
        let lut = &mut self.cores[ci].plan.lut;
        lut[i] = lut[i].wrapping_add(1);
    }

    /// Mutation-test hook: point one feature's arena offset past the
    /// end of the arena — rule V2 must fire, and only V2 (bounds and
    /// LUT are untouched).
    #[doc(hidden)]
    pub fn corrupt_arena_offset(&mut self, ci: usize, f: usize) {
        let end = self.cores[ci].plan.arena.len() + 1;
        self.cores[ci].plan.features[f].off = end;
    }

    /// Mutation-test hook: set the first padding bit (row `n_rows`) in
    /// feature 0's interval-0 bitset — rule V2's padding check must
    /// fire. Returns `false` when the core has no padding bits to
    /// corrupt (empty core, or `n_rows` a multiple of 64).
    #[doc(hidden)]
    pub fn set_arena_padding_bit(&mut self, ci: usize) -> bool {
        let core = &mut self.cores[ci];
        let n_rows = core.leaf.len();
        if n_rows == 0 || n_rows % 64 == 0 || core.plan.features.is_empty() {
            return false;
        }
        let nw = core.plan.n_words;
        let base = match &core.plan.slots {
            Some(slots) => slots[core.plan.features[0].off] as usize * nw,
            None => core.plan.features[0].off,
        };
        core.plan.arena[base + nw - 1] |= 1u64 << (n_rows % 64);
        true
    }

    /// Mutation-test hook: remap feature 0's interval-0 slot of a
    /// deduplicated plan to a different arena slice — the slice a query
    /// resolves to no longer matches the programmed cells, so rule V7's
    /// match-set equivalence check must fire. Returns `false` when the
    /// plan is not deduplicated or has only one distinct slice.
    #[doc(hidden)]
    pub fn corrupt_dedup_slot(&mut self, ci: usize) -> bool {
        let core = &mut self.cores[ci];
        let n_slices = core.plan.arena.len() / core.plan.n_words;
        let base = core.plan.features.first().map(|f| f.off);
        match (&mut core.plan.slots, base) {
            (Some(slots), Some(off)) if n_slices > 1 => {
                slots[off] = (slots[off] + 1) % n_slices as u32;
                true
            }
            _ => false,
        }
    }

    /// Quantizer-bin → 8-bit DAC level: the DAC's full-scale mapping,
    /// saturating at the top level through the same [`dac_level`]
    /// conversion the CAM search paths use. A raw `b * scale` here once
    /// wrapped (u16 overflow) for out-of-range bins — the same bug class
    /// as the PR 2 `search_two_cycle` cast — so all query paths now
    /// share this saturating conversion and stay mutually equivalent on
    /// every input, including bins past `n_bins`.
    #[inline]
    fn scale_bin(&self, b: u16) -> u16 {
        dac_level(b.saturating_mul(self.scale))
    }

    /// Scale a whole batch into DAC level space (arity-checked).
    fn scale_batch(&self, batch: &[Vec<u16>]) -> Vec<Vec<u16>> {
        batch
            .iter()
            .map(|bins| {
                assert_eq!(bins.len(), self.n_features, "feature arity mismatch");
                bins.iter().map(|&b| self.scale_bin(b)).collect()
            })
            .collect()
    }

    /// Inference over quantized bins; returns logits per output column.
    pub fn infer_bins(&self, bins: &[u16]) -> Vec<f32> {
        self.infer_bins_stats(bins).0
    }

    /// Inference + search statistics.
    pub fn infer_bins_stats(&self, bins: &[u16]) -> (Vec<f32>, SearchStats) {
        let (acc, stats) = self.partials_bins_stats(bins);
        (apply_base(&acc, &self.base_score), stats)
    }

    /// Base-free per-class partial sums in f64 — the shard-aggregation
    /// contract: summing each shard engine's `partials_bins` and then
    /// applying `base` exactly as [`CamEngine::infer_bins`] does
    /// (`partial as f32 + base`) reproduces the unsharded logits.
    pub fn partials_bins(&self, bins: &[u16]) -> Vec<f64> {
        self.partials_bins_stats(bins).0
    }

    /// Scalar partial sums + search statistics in one pass (the
    /// agreement gates compare both against the batch paths without
    /// running the per-cell scan twice).
    pub fn partials_bins_stats(&self, bins: &[u16]) -> (Vec<f64>, SearchStats) {
        assert_eq!(bins.len(), self.n_features, "feature arity mismatch");
        // Queries are scaled into the same 8-bit level space as the
        // programmed bounds, modelling the DAC's full-scale mapping
        // (saturating — see `scale_bin`).
        let scaled: Vec<u16> = bins.iter().map(|&b| self.scale_bin(b)).collect();
        let mut acc = vec![0f64; self.n_outputs];
        let mut stats = SearchStats::default();
        for core in &self.cores {
            // DAC conversion (possibly defective) then gated CAM search.
            let q = core.dac.apply_row(&scaled);
            let res = core.cam.search(&q);
            stats.charged_rows += res.charged_rows.iter().sum::<usize>();
            // MMR: resolve matches one at a time, bounded by the
            // iteration budget (§III-A). Defects can produce more matches
            // than trees; the hardware stops after N_trees,core tokens.
            let mut taken = 0usize;
            for (row, &m) in res.matches.iter().enumerate() {
                if !m {
                    continue;
                }
                if taken >= core.n_trees_core {
                    break;
                }
                taken += 1;
                acc[core.class[row] as usize] += core.leaf[row] as f64;
            }
            stats.matches += taken;
        }
        (acc, stats)
    }

    /// Batched inference over quantized bins; logits per row.
    /// Bit-identical to mapping [`CamEngine::infer_bins`] over the batch.
    pub fn infer_batch(&self, batch: &[Vec<u16>]) -> Vec<Vec<f32>> {
        self.infer_batch_stats(batch).0
    }

    /// Batched inference + search statistics summed over the batch.
    pub fn infer_batch_stats(&self, batch: &[Vec<u16>]) -> (Vec<Vec<f32>>, SearchStats) {
        let (accs, stats) = self.partials_batch_stats(batch);
        let logits = accs.iter().map(|acc| apply_base(acc, &self.base_score)).collect();
        (logits, stats)
    }

    /// Batched base-free partial sums — the batched form of
    /// [`CamEngine::partials_bins`], bit-identical per row.
    pub fn partials_batch(&self, batch: &[Vec<u16>]) -> Vec<Vec<f64>> {
        self.partials_batch_stats(batch).0
    }

    /// The indexed batch path: per core, intersect per-feature match
    /// sets from the plan's interval arena as u64 bitset words instead
    /// of scanning every cell per row (interval resolution by binary
    /// search — the planned path's measured baseline). The
    /// queued-segment gating of [`CoreCam::search`] is reproduced by
    /// snapshotting the active-set population at each segment boundary
    /// (`charged_rows`), and MMR consumes set bits in ascending row
    /// order under the same `n_trees_core` budget — so partials, logits
    /// and [`SearchStats`] (summed over the batch) are bit-identical to
    /// the scalar path.
    pub fn partials_batch_stats(&self, batch: &[Vec<u16>]) -> (Vec<Vec<f64>>, SearchStats) {
        let mut acc = vec![vec![0f64; self.n_outputs]; batch.len()];
        let mut stats = SearchStats::default();
        if batch.is_empty() {
            return (acc, stats);
        }
        // Same DAC full-scale mapping as the scalar path.
        let scaled = self.scale_batch(batch);
        let n_segments = self.n_features.div_ceil(ARRAY_COLS).max(1);
        let mut active: Vec<u64> = Vec::new();
        // Cores outer, batch rows inner: one core's plan stays cache-hot
        // across the whole batch, and each row still accumulates its
        // per-core contributions in core order (the scalar f64 order).
        for core in &self.cores {
            let plan = &core.plan;
            for (q, row_acc) in scaled.iter().zip(acc.iter_mut()) {
                active.clear();
                active.extend_from_slice(&plan.full);
                for s in 0..n_segments {
                    // Queued gating: segment s charges the rows still
                    // active after the previous segments' features.
                    let live: usize = active.iter().map(|w| w.count_ones() as usize).sum();
                    stats.charged_rows += live;
                    let c0 = s * ARRAY_COLS;
                    let c1 = ((s + 1) * ARRAY_COLS).min(self.n_features);
                    for f in c0..c1 {
                        let m = plan.rows_matching_indexed(f, core.dac.apply(f, q[f]));
                        for (a, &w) in active.iter_mut().zip(m) {
                            *a &= w;
                        }
                    }
                    // Later segments would charge popcount(∅) = 0 rows.
                    if active.iter().all(|&w| w == 0) {
                        break;
                    }
                }
                // MMR over set bits in ascending row order, bounded by
                // the core's iteration budget — the scalar loop exactly.
                let mut taken = 0usize;
                'mmr: for (w, &word0) in active.iter().enumerate() {
                    let mut word = word0;
                    while word != 0 {
                        if taken >= core.n_trees_core {
                            break 'mmr;
                        }
                        let row = w * 64 + word.trailing_zeros() as usize;
                        taken += 1;
                        row_acc[core.class[row] as usize] += core.leaf[row] as f64;
                        word &= word - 1;
                    }
                }
                stats.matches += taken;
            }
        }
        (acc, stats)
    }

    /// Batched inference through the planned path; logits per row.
    /// Bit-identical to [`CamEngine::infer_batch`] (and hence to the
    /// scalar path) for every `threads` value.
    pub fn infer_planned(&self, batch: &[Vec<u16>], threads: usize) -> Vec<Vec<f32>> {
        self.infer_planned_stats(batch, threads).0
    }

    /// Planned inference + search statistics summed over the batch.
    pub fn infer_planned_stats(
        &self,
        batch: &[Vec<u16>],
        threads: usize,
    ) -> (Vec<Vec<f32>>, SearchStats) {
        let (accs, stats) = self.partials_planned_stats(batch, threads);
        let logits = accs.iter().map(|acc| apply_base(acc, &self.base_score)).collect();
        (logits, stats)
    }

    /// Planned base-free partial sums — the planned form of
    /// [`CamEngine::partials_batch`], bit-identical per row.
    pub fn partials_planned(&self, batch: &[Vec<u16>], threads: usize) -> Vec<Vec<f64>> {
        self.partials_planned_stats(batch, threads).0
    }

    /// The planned hot path: LUT interval resolution + arena bitsets +
    /// query-blocked traversal, with cores partitioned across a
    /// `std::thread::scope` pool (`threads`; 0 = one worker per
    /// available CPU, capped at the core count).
    ///
    /// **Determinism contract** (docs/adr/002-planned-execution.md):
    /// each worker owns a contiguous, ascending range of cores and
    /// records every MMR hit as a `(class, leaf)` pair per batch row in
    /// (core, ascending-row) order; the merge then replays those adds
    /// worker by worker in ascending core order. The resulting f64 add
    /// chain per row is *exactly* the scalar path's interleaved
    /// accumulation, so partials, logits and [`SearchStats`] are
    /// bit-identical for every thread count. (Summing per-worker f64
    /// subtotals instead would re-associate the chain and drift.)
    pub fn partials_planned_stats(
        &self,
        batch: &[Vec<u16>],
        threads: usize,
    ) -> (Vec<Vec<f64>>, SearchStats) {
        let mut acc = vec![vec![0f64; self.n_outputs]; batch.len()];
        let mut stats = SearchStats::default();
        if batch.is_empty() || self.cores.is_empty() {
            return (acc, stats);
        }
        let scaled = self.scale_batch(batch);
        let t = self.effective_threads(threads);
        if t <= 1 {
            // Single worker: accumulate in place — the emit order is the
            // scalar chain already, so no hit buffering is needed.
            execute_planned(&self.cores, self.n_features, &scaled, &mut stats, |row, c, l| {
                acc[row][c as usize] += l as f64;
            });
            return (acc, stats);
        }
        let chunk = self.cores.len().div_ceil(t);
        let n_features = self.n_features;
        let results: Vec<(MatchHits, SearchStats)> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .cores
                .chunks(chunk)
                .map(|cores| {
                    let scaled = &scaled;
                    s.spawn(move || {
                        let mut hits: MatchHits = vec![Vec::new(); scaled.len()];
                        let mut st = SearchStats::default();
                        execute_planned(cores, n_features, scaled, &mut st, |row, c, l| {
                            hits[row].push((c, l));
                        });
                        (hits, st)
                    })
                })
                .collect();
            // Join in spawn order = ascending core order.
            handles
                .into_iter()
                .map(|h| h.join().expect("planned execution worker panicked"))
                .collect()
        });
        for (hits, st) in results {
            stats.charged_rows += st.charged_rows;
            stats.matches += st.matches;
            for (row_acc, row_hits) in acc.iter_mut().zip(hits) {
                for (class, leaf) in row_hits {
                    row_acc[class as usize] += leaf as f64;
                }
            }
        }
        (acc, stats)
    }

    /// Resolve the `threads` knob: 0 = available parallelism, always at
    /// least 1 and never more workers than cores.
    fn effective_threads(&self, threads: usize) -> usize {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        t.clamp(1, self.cores.len().max(1))
    }

    /// Quantize a raw feature row with the program's quantizer, then infer.
    pub fn infer_row(&self, program: &CamProgram, row: &[f32]) -> Vec<f32> {
        let bins = program.quantizer.bin_row(row);
        self.infer_bins(&bins)
    }

    /// Task-level decision from logits (the co-processor's job, §III-A).
    pub fn decide(&self, logits: &[f32]) -> f32 {
        match self.task {
            Task::Regression => logits[0],
            Task::Binary => (logits[0] > 0.0) as usize as f32,
            Task::MultiClass(_) => {
                let mut best = 0usize;
                for c in 1..logits.len() {
                    if logits[c] > logits[best] {
                        best = c;
                    }
                }
                best as f32
            }
        }
    }

    /// End-to-end prediction for a raw row.
    pub fn predict(&self, program: &CamProgram, row: &[f32]) -> f32 {
        let l = self.infer_row(program, row);
        self.decide(&l)
    }
}

/// One worker's MMR output: for each batch row, the ordered `(class,
/// leaf)` add chain its core range contributes. Kept as raw adds — not
/// f64 subtotals — so the merge can replay the scalar path's exact
/// accumulation order (f64 addition is not associative).
type MatchHits = Vec<Vec<(u16, f32)>>;

/// Execute the planned path over a contiguous core range: per core,
/// query-blocked traversal of the [`CorePlan`] (LUT interval resolution,
/// arena bitsets), queued-segment charge accounting, and MMR hit
/// extraction in ascending row order. `scaled` is the batch in DAC level
/// space (every level ≤ 255). Each MMR hit is handed to `emit(row,
/// class, leaf)` in the scalar path's exact order — the single-worker
/// path accumulates f64 directly, the threaded path buffers
/// [`MatchHits`] for the ordered merge.
fn execute_planned<F: FnMut(usize, u16, f32)>(
    cores: &[EngineCore],
    n_features: usize,
    scaled: &[Vec<u16>],
    stats: &mut SearchStats,
    mut emit: F,
) {
    let n_segments = n_features.div_ceil(ARRAY_COLS).max(1);
    // One active-set arena for the whole block (SoA: query-major rows of
    // `n_words` words), reused across blocks and cores.
    let mut active: Vec<u64> = Vec::new();
    let mut alive = [false; QUERY_BLOCK];
    for core in cores {
        let plan = &core.plan;
        let nw = plan.n_words;
        let core_live = plan.full.iter().any(|&w| w != 0);
        for (b, block) in scaled.chunks(QUERY_BLOCK).enumerate() {
            let base = b * QUERY_BLOCK;
            let bs = block.len();
            active.clear();
            for _ in 0..bs {
                active.extend_from_slice(&plan.full);
            }
            alive[..bs].fill(core_live);
            for s in 0..n_segments {
                // Queued gating: segment s charges the rows still active
                // after the previous segments' features; a query whose
                // active set already drained charges popcount(∅) = 0 and
                // is skipped outright (the empty-segment short-circuit).
                for q in 0..bs {
                    if alive[q] {
                        stats.charged_rows += active[q * nw..(q + 1) * nw]
                            .iter()
                            .map(|w| w.count_ones() as usize)
                            .sum::<usize>();
                    }
                }
                let c0 = s * ARRAY_COLS;
                let c1 = ((s + 1) * ARRAY_COLS).min(n_features);
                for f in c0..c1 {
                    // Blocked traversal: every live query in the block
                    // ANDs against this feature's (cache-hot) interval
                    // slices before the walk moves to the next feature.
                    for q in 0..bs {
                        if !alive[q] {
                            continue;
                        }
                        let m = plan.rows_matching(f, core.dac.apply(f, block[q][f]));
                        for (a, &w) in active[q * nw..(q + 1) * nw].iter_mut().zip(m) {
                            *a &= w;
                        }
                    }
                }
                let mut any = false;
                for q in 0..bs {
                    if alive[q] {
                        alive[q] = active[q * nw..(q + 1) * nw].iter().any(|&w| w != 0);
                    }
                    any |= alive[q];
                }
                if !any {
                    break;
                }
            }
            // MMR over set bits in ascending row order, bounded by the
            // core's iteration budget — emitted as (class, leaf) adds
            // in the scalar path's order.
            for q in 0..bs {
                let mut taken = 0usize;
                'mmr: for (w, &word0) in active[q * nw..(q + 1) * nw].iter().enumerate() {
                    let mut word = word0;
                    while word != 0 {
                        if taken >= core.n_trees_core {
                            break 'mmr;
                        }
                        let row = w * 64 + word.trailing_zeros() as usize;
                        taken += 1;
                        emit(base + q, core.class[row], core.leaf[row]);
                        word &= word - 1;
                    }
                }
                stats.matches += taken;
            }
        }
    }
}

/// One core's defect draw: scaled cell image + perturbation + DAC error
/// table, consumed from `crng` in a single canonical order. This is the
/// **only** definition of the per-core defect stream — both
/// [`CamEngine::with_defects`] (which keeps the cells/DAC) and
/// [`defect_affected_trees`] (which keeps the changed-cell report) call
/// it, so the replay can never desynchronize from the engine.
fn core_defect_draw(
    program: &CamProgram,
    core: &super::program::CoreImage,
    defects: DefectSpec,
    scale: u16,
    crng: &mut Rng,
) -> (Vec<MacroCell>, Vec<usize>, DacErrors) {
    let mut cells = Vec::with_capacity(core.rows.len() * program.n_features);
    for r in &core.rows {
        for f in 0..program.n_features {
            // Bounds are scaled into the 8-bit macro-cell level space so
            // 4-bit programs exercise the same hardware path with coarser
            // levels.
            cells.push(MacroCell::new(r.lo[f] * scale, r.hi[f] * scale));
        }
    }
    let changed = inject_memristor_defects_tracked(&mut cells, defects.memristor_pct, crng);
    let dac = DacErrors::draw(program.n_features, defects.dac_pct, crng);
    (cells, changed, dac)
}

/// Tree ids whose CAM rows land on cells perturbed by the defect draw
/// `(defects, seed)` — replayed over the *identical* rng stream
/// [`CamEngine::with_defects`] consumes (shared `core_defect_draw`), so
/// the returned set is exactly the set of trees whose deployed rows
/// differ from their ideal programming in that engine. This is the
/// "known defect map" oracle of the defect-aware retrain loop
/// (`trees::hat::defect_aware_retrain`).
pub fn defect_affected_trees(program: &CamProgram, defects: DefectSpec, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed ^ 0xDEFEC7);
    let scale = (crate::cam::MACRO_BINS / program.n_bins.max(1)) as u16;
    let mut affected: Vec<u32> = Vec::new();
    for (ci, c) in program.cores.iter().enumerate() {
        let mut crng = rng.fork(ci as u64);
        let (_, changed, _) = core_defect_draw(program, c, defects, scale, &mut crng);
        for idx in changed {
            affected.push(c.rows[idx / program.n_features].tree);
        }
    }
    affected.sort_unstable();
    affected.dedup();
    affected
}

/// Task score (accuracy, or R² for regression) of `program` served
/// through a *defective* engine — the deployment-side objective the
/// defect-aware retrain loop maximizes. Rows go through the batched
/// interval-index path (bit-identical to the scalar path, contract 4),
/// which is what makes per-pass probing over a large eval set cheap.
pub fn defective_score(
    program: &CamProgram,
    defects: DefectSpec,
    seed: u64,
    data: &Dataset,
) -> f64 {
    let engine = CamEngine::with_defects(program, defects, seed);
    let batch: Vec<Vec<u16>> =
        (0..data.n_rows()).map(|i| program.quantizer.bin_row(data.row(i))).collect();
    let preds: Vec<f32> =
        engine.infer_batch(&batch).iter().map(|logits| engine.decide(logits)).collect();
    match data.task {
        Task::Regression => metrics::r2(&preds, &data.y),
        _ => metrics::accuracy(&preds, &data.y),
    }
}

/// Pre-wired defect-aware HAT retraining: compiles each candidate model
/// with `options`, identifies the trees whose rows land on the chip's
/// known defect draw `(defects, seed)` and re-fits them
/// ([`crate::trees::hat::refit_trees`]), keeping the pass that scores
/// best on `eval` through the defective engine. An input model that does
/// not compile is an `Err`; mid-loop compile failures of *retrained*
/// candidates score `-inf` so an earlier pass wins instead of
/// panicking. Exactly one compile per probe (= per retrain pass, plus
/// one for the input model).
pub fn hat_defect_retrain(
    train: &Dataset,
    eval: &Dataset,
    model: Ensemble,
    params: &HatParams,
    options: &CompileOptions,
    defects: DefectSpec,
    seed: u64,
) -> Result<(Ensemble, RetrainReport), CompileError> {
    // The input model's compile error (if any) surfaces from its own
    // probe — no separate validation compile.
    let first_compile_error: std::cell::RefCell<Option<CompileError>> =
        std::cell::RefCell::new(None);
    let probe = |m: &Ensemble| match compile(m, options) {
        Ok(p) => {
            (defect_affected_trees(&p, defects, seed), defective_score(&p, defects, seed, eval))
        }
        Err(e) => {
            let mut slot = first_compile_error.borrow_mut();
            if slot.is_none() {
                *slot = Some(e);
            }
            (Vec::new(), f64::NEG_INFINITY)
        }
    };
    let (best, report) = defect_aware_retrain(train, model, params, &probe);
    // The first probe is always the input model; if *it* failed to
    // compile, the loop never ran (empty affected set ⇒ zero passes) and
    // the stashed error is the input's. With passes > 0 the input
    // compiled, and any stashed error came from a discarded retrain
    // candidate — already handled by its -inf score.
    if report.passes == 0 {
        if let Some(e) = first_compile_error.borrow_mut().take() {
            return Err(e);
        }
    }
    Ok((best, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::program::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, rf, GbdtParams, RfParams};

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn engine_matches_cpu_reference_binary() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 15, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        for i in 0..200 {
            let row = d.row(i);
            let cam = e.infer_row(&p, row);
            let cpu = m.logits(row);
            assert!(close(cam[0], cpu[0]), "row {i}: cam {} vs cpu {}", cam[0], cpu[0]);
            assert_eq!(e.predict(&p, row), m.predict(row));
        }
    }

    #[test]
    fn engine_matches_cpu_reference_multiclass() {
        let d = by_name("eye").unwrap().generate_n(1200);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
            None,
        );
        // Force a multi-core layout to exercise placement + reduction.
        let p = compile(&m, &CompileOptions { core_rows: 48, ..Default::default() }).unwrap();
        assert!(p.cores_per_replica() > 1);
        let e = CamEngine::new(&p);
        for i in 0..150 {
            let row = d.row(i);
            let cam = e.infer_row(&p, row);
            let cpu = m.logits(row);
            for k in 0..cam.len() {
                assert!(close(cam[k], cpu[k]), "row {i} class {k}: {} vs {}", cam[k], cpu[k]);
            }
        }
    }

    #[test]
    fn engine_matches_cpu_reference_rf_regression() {
        let d = by_name("rossmann").unwrap().generate_n(1000);
        let m = rf::train(&d, &RfParams { n_estimators: 10, max_leaves: 32, ..Default::default() });
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        for i in 0..100 {
            let row = d.row(i);
            assert!(close(e.infer_row(&p, row)[0], m.logits(row)[0]), "row {i}");
        }
    }

    #[test]
    fn four_bit_program_runs_on_macro_cells() {
        let d = by_name("telco").unwrap().generate_n(900);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, n_bits: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_bins, 16);
        let e = CamEngine::new(&p);
        for i in 0..100 {
            let row = d.row(i);
            assert!(close(e.infer_row(&p, row)[0], m.logits(row)[0]), "row {i}");
        }
    }

    #[test]
    fn defects_degrade_gracefully() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 20, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let clean = CamEngine::new(&p);
        let dirty = CamEngine::with_defects(&p, DefectSpec::memristor(0.3), 42);
        let mut clean_hits = 0;
        let mut dirty_hits = 0;
        let n = 400;
        for i in 0..n {
            let row = d.row(i);
            clean_hits += (clean.predict(&p, row) == d.y[i]) as usize;
            dirty_hits += (dirty.predict(&p, row) == d.y[i]) as usize;
        }
        let (ca, da) = (clean_hits as f64 / n as f64, dirty_hits as f64 / n as f64);
        // Heavy defects must hurt but the ensemble keeps it above chance.
        assert!(da <= ca + 0.02, "defects improved accuracy? {ca} vs {da}");
        assert!(da > 0.5, "catastrophic collapse: {da}");
    }

    #[test]
    fn small_defect_rate_nearly_harmless() {
        // Paper: ~0.2% flip probability → accuracy drop < 0.5%.
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 20, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let clean = CamEngine::new(&p);
        let dirty = CamEngine::with_defects(&p, DefectSpec::memristor(0.002), 7);
        let n = 400;
        let mut agree = 0;
        for i in 0..n {
            let row = d.row(i);
            agree += (clean.predict(&p, row) == dirty.predict(&p, row)) as usize;
        }
        assert!(agree as f64 / n as f64 > 0.97, "agreement {}", agree as f64 / n as f64);
    }

    /// Cheap in-module smoke of the batched/scalar bit-identity contract
    /// (the exhaustive property suite — tasks × precisions × defects ×
    /// shard plans × thread counts — lives in
    /// `rust/tests/batch_agreement.rs`).
    #[test]
    fn batched_path_smoke_bit_identical() {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        let batch: Vec<Vec<u16>> = (0..32).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        let (partials, stats) = e.partials_batch_stats(&batch);
        let logits = e.infer_batch(&batch);
        let (mut charged, mut matches) = (0usize, 0usize);
        for (i, bins) in batch.iter().enumerate() {
            assert_eq!(partials[i], e.partials_bins(bins), "row {i} partials");
            let (want, s) = e.infer_bins_stats(bins);
            assert_eq!(logits[i], want, "row {i} logits");
            charged += s.charged_rows;
            matches += s.matches;
        }
        assert_eq!(stats.charged_rows, charged, "charged_rows drifted");
        assert_eq!(stats.matches, matches, "matches drifted");
        // The planned path rides the same contract, per thread count.
        for threads in [1usize, 2, 8] {
            let (pp, ps) = e.partials_planned_stats(&batch, threads);
            assert_eq!(pp, partials, "planned({threads}T) partials");
            assert_eq!(e.infer_planned(&batch, threads), logits, "planned({threads}T) logits");
            assert_eq!(ps.charged_rows, charged, "planned({threads}T) charged_rows");
            assert_eq!(ps.matches, matches, "planned({threads}T) matches");
        }
        // Empty batches are a no-op, not a panic.
        let (empty, zero) = e.partials_batch_stats(&[]);
        assert!(empty.is_empty());
        assert_eq!((zero.charged_rows, zero.matches), (0, 0));
        let (empty, zero) = e.partials_planned_stats(&[], 4);
        assert!(empty.is_empty());
        assert_eq!((zero.charged_rows, zero.matches), (0, 0));
    }

    /// A one-core engine over hand-laid cells: the direct harness for the
    /// `CorePlan` edge cases below (in-module so private fields are
    /// constructible).
    fn handmade_engine(
        n_rows: usize,
        n_features: usize,
        cells: Vec<MacroCell>,
        n_trees_core: usize,
    ) -> CamEngine {
        let plan = CorePlan::build(n_rows, n_features, &cells, false);
        CamEngine {
            task: Task::Binary,
            n_outputs: 1,
            base_score: vec![0.0],
            cores: vec![EngineCore {
                cam: CoreCam::from_cells(n_rows, n_features, cells),
                plan,
                leaf: (0..n_rows).map(|r| 0.25 + r as f32).collect(),
                class: vec![0; n_rows],
                n_trees_core,
                dac: DacErrors::none(n_features),
            }],
            n_features,
            scale: 1,
        }
    }

    /// All three paths on one engine/batch, compared bit for bit.
    fn assert_paths_agree(e: &CamEngine, batch: &[Vec<u16>], label: &str) {
        let (batched, bstats) = e.partials_batch_stats(batch);
        let (mut charged, mut matches) = (0usize, 0usize);
        for (i, bins) in batch.iter().enumerate() {
            let (scalar, s) = e.partials_bins_stats(bins);
            assert_eq!(batched[i], scalar, "{label}: row {i} batched vs scalar");
            charged += s.charged_rows;
            matches += s.matches;
        }
        assert_eq!((bstats.charged_rows, bstats.matches), (charged, matches), "{label}: stats");
        for threads in [1usize, 2, 8] {
            let (planned, pstats) = e.partials_planned_stats(batch, threads);
            assert_eq!(planned, batched, "{label}: planned({threads}T) partials");
            assert_eq!(
                (pstats.charged_rows, pstats.matches),
                (charged, matches),
                "{label}: planned({threads}T) stats"
            );
        }
    }

    /// The LUT is a tabulated `partition_point`: both interval
    /// resolutions must return the identical arena slice for every 8-bit
    /// level, on every feature, including bound levels 255 and 256.
    #[test]
    fn plan_lut_matches_binary_search_everywhere() {
        use crate::util::prop;
        prop::check(50, 0x1007, |g| {
            let n_rows = g.usize_in(1, 70);
            let n_features = g.usize_in(1, 6);
            let mut cells = Vec::with_capacity(n_rows * n_features);
            for _ in 0..n_rows * n_features {
                let lo = g.usize_in(0, 257) as u16;
                let hi = g.usize_in(0, 257) as u16;
                cells.push(MacroCell::new(lo, hi));
            }
            // Both addressing modes, both resolutions: all four agree.
            let plan = CorePlan::build(n_rows, n_features, &cells, false);
            let deduped = CorePlan::build(n_rows, n_features, &cells, true);
            for f in 0..n_features {
                for q in 0..MACRO_BINS {
                    prop::require(
                        plan.rows_matching(f, q) == plan.rows_matching_indexed(f, q),
                        format!("f={f} q={q} rows={n_rows}"),
                    )?;
                    prop::require(
                        deduped.rows_matching(f, q) == plan.rows_matching(f, q)
                            && deduped.rows_matching_indexed(f, q) == plan.rows_matching(f, q),
                        format!("dedup f={f} q={q} rows={n_rows}"),
                    )?;
                }
            }
            prop::require(
                deduped.arena.len() <= plan.arena.len(),
                format!("dedup arena grew: {} > {}", deduped.arena.len(), plan.arena.len()),
            )?;
            Ok(())
        });
    }

    /// `CorePlan` edge cases (ISSUE 4 satellite): features with zero
    /// useful bound levels (don't-care and never-match columns), a
    /// single distinct level, and windows touching level 255 — each
    /// bit-identical across scalar/indexed/planned paths.
    #[test]
    fn plan_edge_level_features_agree() {
        let n_rows = 5;
        // f0: don't care (bounds collapse to {256} → one reachable
        //     interval); f1: single distinct level 7 shared by all rows;
        // f2: top window [250, 256) — level 255 must match;
        // f3: mixed per-row windows including an empty [5, 5).
        let mut cells = Vec::new();
        for r in 0..n_rows {
            cells.push(MacroCell::DONT_CARE);
            cells.push(MacroCell::new(0, 7));
            cells.push(MacroCell::new(250, MACRO_BINS));
            cells.push(match r {
                0 => MacroCell::new(5, 5),   // empty window: never matches
                1 => MacroCell::new(200, 10), // inverted: never matches
                _ => MacroCell::DONT_CARE,
            });
        }
        let e = handmade_engine(n_rows, 4, cells, n_rows);
        let batch: Vec<Vec<u16>> = vec![
            vec![0, 0, 250, 0],
            vec![255, 6, 255, 255], // level 255 everywhere it matters
            vec![17, 7, 254, 99],   // f1 boundary: 7 is outside [0,7)
            vec![255, 255, 249, 5],
        ];
        assert_paths_agree(&e, &batch, "edge-levels");
        // Spot-check the semantics the paths agreed on: query 1 matches
        // rows 2.. on every feature (f3 kills rows 0 and 1).
        let p = e.partials_bins(&batch[1]);
        let want: f64 = (2..n_rows).map(|r| (0.25 + r as f32) as f64).sum();
        assert_eq!(p[0], want);
        // Query 2 matches nothing (f1 level 7 ≥ hi).
        assert_eq!(e.partials_bins(&batch[2])[0], 0.0);
    }

    /// Empty-after-gating short-circuit (ISSUE 4 satellite): when
    /// segment 0 drains the active set, later segments charge 0 rows on
    /// every path, and the planned path's skip of dead queries must not
    /// change the accounting.
    #[test]
    fn plan_short_circuits_empty_tail_segments() {
        let n_rows = 8;
        let n_features = 130; // two queued segments
        let mut cells = vec![MacroCell::DONT_CARE; n_rows * n_features];
        for r in 0..n_rows {
            cells[r * n_features] = MacroCell::new(10, 20);
        }
        let e = handmade_engine(n_rows, n_features, cells, n_rows);
        // Query misses every first-segment window → segment 1 never
        // charges.
        let miss = vec![0u16; n_features];
        let (p, stats) = e.partials_bins_stats(&miss);
        assert_eq!(p[0], 0.0);
        assert_eq!(stats.charged_rows, n_rows, "only segment 0 charges");
        assert_eq!(stats.matches, 0);
        // Query hits → both segments charge all rows.
        let mut hit = vec![0u16; n_features];
        hit[0] = 15;
        let (_, stats) = e.partials_bins_stats(&hit);
        assert_eq!(stats.charged_rows, 2 * n_rows);
        assert_eq!(stats.matches, n_rows);
        // And the batched/planned paths reproduce both, mixed in one
        // batch (the short-circuit applies per query, not per block).
        assert_paths_agree(&e, &[miss, hit], "short-circuit");
    }

    /// Defect-modified rows (ISSUE 4 satellite): the plan is built from
    /// the perturbed cells, so planned == scalar must hold on defective
    /// engines — including the DAC-error query offsets.
    #[test]
    fn plan_agrees_on_defect_modified_rows() {
        let d = by_name("churn").unwrap().generate_n(900);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 8, ..Default::default() },
            None,
        );
        // Small cores force a multi-core layout so thread partitioning
        // splits real work.
        let p = compile(&m, &CompileOptions { core_rows: 64, ..Default::default() }).unwrap();
        let e = CamEngine::with_defects(
            &p,
            DefectSpec { memristor_pct: 0.3, dac_pct: 0.2 },
            41,
        );
        let batch: Vec<Vec<u16>> = (0..24).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        assert_paths_agree(&e, &batch, "defects");
    }

    #[test]
    fn defect_affected_trees_replays_the_engine_draw() {
        let d = by_name("churn").unwrap().generate_n(900);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        // No defects → nothing affected.
        assert!(defect_affected_trees(&p, DefectSpec::NONE, 3).is_empty());
        // Saturated defects → (essentially) every tree affected.
        let all = defect_affected_trees(&p, DefectSpec::memristor(1.0), 3);
        assert_eq!(all.len(), p.n_trees, "pct=1 must touch every tree");
        assert!(all.iter().all(|&t| (t as usize) < p.n_trees));
        // Deterministic replay.
        let a = defect_affected_trees(&p, DefectSpec::memristor(0.05), 11);
        let b = defect_affected_trees(&p, DefectSpec::memristor(0.05), 11);
        assert_eq!(a, b);
        // When the replay says "no tree affected", the defective engine
        // must be bit-identical to the clean one (the whole point of
        // replaying the engine's exact rng stream).
        let clean = CamEngine::new(&p);
        let spec = DefectSpec::memristor(0.001);
        let mut verified = false;
        for seed in 0..64u64 {
            if !defect_affected_trees(&p, spec, seed).is_empty() {
                continue;
            }
            let dirty = CamEngine::with_defects(&p, spec, seed);
            for i in 0..100 {
                let bins = p.quantizer.bin_row(d.row(i));
                assert_eq!(clean.infer_bins(&bins), dirty.infer_bins(&bins), "seed {seed} row {i}");
            }
            verified = true;
            break;
        }
        assert!(verified, "no defect-free draw found in 64 seeds — shrink the program");
    }

    #[test]
    fn defective_score_matches_clean_engine_without_defects() {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let s = defective_score(&p, DefectSpec::NONE, 0, &d);
        assert!((0.0..=1.0).contains(&s));
        let e = CamEngine::new(&p);
        let mut hits = 0usize;
        for i in 0..d.n_rows() {
            hits += (e.predict(&p, d.row(i)) == d.y[i]) as usize;
        }
        assert!((s - hits as f64 / d.n_rows() as f64).abs() < 1e-12);
    }

    #[test]
    fn hat_defect_retrain_end_to_end_never_degrades() {
        use crate::trees::hat::{self, HatParams};
        let d = by_name("churn").unwrap().generate_n(1500);
        let split = d.split(0.7, 0.0, 23);
        let params = HatParams {
            deploy_bits: 4,
            gbdt: GbdtParams { n_rounds: 10, max_leaves: 8, ..Default::default() },
            retrain_passes: 2,
            ..Default::default()
        };
        let model = hat::train(&split.train, &params, None);
        let spec = DefectSpec::memristor(0.1);
        let (better, report) = hat_defect_retrain(
            &split.train,
            &split.test,
            model,
            &params,
            &CompileOptions::default(),
            spec,
            7,
        )
        .unwrap();
        assert!(report.passes <= 2);
        assert!(
            report.final_score >= report.initial_score,
            "retrain degraded the deployed score: {report:?}"
        );
        // The returned model still compiles and deploys losslessly.
        let (_, hat_report) =
            crate::compiler::program::compile_for_deploy(&better, 4, &CompileOptions::default())
                .unwrap();
        hat_report.assert_lossless("retrained model");
    }

    #[test]
    fn stats_report_charged_rows() {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        let bins = p.quantizer.bin_row(d.row(0));
        let (_, stats) = e.infer_bins_stats(&bins);
        // Exactly one row matches per tree.
        assert_eq!(stats.matches, 4);
        assert!(stats.charged_rows >= p.total_rows());
    }
}
