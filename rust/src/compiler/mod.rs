//! The X-TIME compiler (paper §II-D, §III-A, §III-D): trained ensembles →
//! CAM threshold maps, core placement, NoC router configuration — plus the
//! bit-accurate functional engine used as the reference for the cycle
//! simulator and the XLA runtime.

pub mod compress;
pub mod engine;
pub mod noc;
pub mod partition;
pub mod paths;
pub mod program;

pub use compress::{compress_program, CompressionReport, CoreLayout, Unit, WordImage};
pub use engine::{
    apply_base, defect_affected_trees, defective_score, hat_defect_retrain, CamEngine, PlanView,
    SearchStats,
};
pub use noc::{NocConfig, Router};
pub use partition::{partition, PartitionError, PartitionOptions, ShardPlan, ShardStrategy};
pub use paths::{extract_rows, snap_threshold, snap_tree, CamRow, HatReport};
pub use program::{
    compile, compile_for_deploy, requantize, CamProgram, CompileError, CompileOptions, CoreImage,
    CHIP_CORES,
};
