//! The X-TIME compiler (paper §II-D, §III-A, §III-D): trained ensembles →
//! CAM threshold maps, core placement, NoC router configuration — plus the
//! bit-accurate functional engine used as the reference for the cycle
//! simulator and the XLA runtime.

pub mod engine;
pub mod noc;
pub mod partition;
pub mod paths;
pub mod program;

pub use engine::{apply_base, CamEngine, SearchStats};
pub use noc::{NocConfig, Router};
pub use partition::{partition, PartitionError, PartitionOptions, ShardPlan, ShardStrategy};
pub use paths::{extract_rows, CamRow};
pub use program::{compile, CamProgram, CompileError, CompileOptions, CoreImage, CHIP_CORES};
