//! CAM program: the compiled form of a tree ensemble — core images,
//! replication and NoC configuration (paper §III-A, §III-D).

use super::compress::{compress_program, CoreLayout};
use super::noc::NocConfig;
use super::paths::{extract_rows, snap_tree, CamRow, HatReport};
use crate::cam::CORE_ROWS;
use crate::data::{FeatureQuantizer, Task};
use crate::trees::{Ensemble, Node};
use crate::util::Json;

/// Chip capacity (paper: 4096 cores, 256 words × 130 features per core).
pub const CHIP_CORES: usize = 4096;

/// One core's image: CAM rows plus metadata for the MMR/SRAM/ACC stages.
#[derive(Clone, Debug)]
pub struct CoreImage {
    pub rows: Vec<CamRow>,
    /// Tree ids mapped to this core (`N_trees,core` = len).
    pub trees: Vec<u32>,
    /// Class all trees in this core contribute to (Fig. 7b invariant).
    pub class: u16,
    /// Replica (batch slot) this core belongs to (Fig. 7c input batching).
    pub replica: u32,
}

impl CoreImage {
    pub fn n_trees_core(&self) -> usize {
        self.trees.len()
    }
}

/// Compilation options.
#[derive(Clone, Copy, Debug)]
pub struct CompileOptions {
    /// Replicate the model into unused cores to serve batched inputs
    /// (Fig. 7c). 0 = auto (fill the chip), 1 = no replication.
    pub replicas: usize,
    /// Core word capacity (tests shrink this to force multi-core layouts).
    pub core_rows: usize,
    /// Chip core budget.
    pub chip_cores: usize,
    /// Run the sparsity-aware capacity compression pass
    /// (`compiler::compress`, DESIGN.md §5 contract 11) and attach the
    /// physical [`CoreLayout`]s to the program. Bit-identical to an
    /// uncompressed compile on every inference path.
    pub compress: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            replicas: 1,
            core_rows: CORE_ROWS,
            chip_cores: CHIP_CORES,
            compress: false,
        }
    }
}

/// A compiled ensemble ready for the functional engine, the cycle
/// simulator and the XLA runtime.
#[derive(Clone, Debug)]
pub struct CamProgram {
    pub name: String,
    pub task: Task,
    pub n_features: usize,
    /// Quantizer bin count (`2^n_bits`).
    pub n_bins: u16,
    pub n_bits: u8,
    pub base_score: Vec<f32>,
    /// Core images of replica 0; replicas are identical copies.
    pub cores: Vec<CoreImage>,
    pub n_replicas: usize,
    pub noc: NocConfig,
    pub quantizer: FeatureQuantizer,
    /// Total trees in the source ensemble.
    pub n_trees: usize,
    /// Physical capacity layouts, one per core, when the program was
    /// compressed (`compiler::compress`; contract 11). `None` = the
    /// physical image is the logical rows, one word each. The layouts
    /// are an annotation: inference always evaluates the logical rows.
    pub layouts: Option<Vec<CoreLayout>>,
}

/// Compiler error.
#[derive(Debug, PartialEq)]
pub enum CompileError {
    /// A tree has more leaves than a core has words.
    TreeTooLarge { tree: u32, leaves: usize, capacity: usize },
    /// Model needs more cores than the chip provides.
    ChipOverflow { needed: usize, available: usize },
    /// Quantizer precision exceeds the CAM's 8-bit macro-cell.
    PrecisionUnsupported { n_bits: u8 },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::TreeTooLarge { tree, leaves, capacity } => {
                write!(f, "tree {tree} has {leaves} leaves > core capacity {capacity}")
            }
            CompileError::ChipOverflow { needed, available } => {
                write!(f, "model needs {needed} cores > {available} available")
            }
            CompileError::PrecisionUnsupported { n_bits } => {
                write!(f, "{n_bits}-bit features exceed the 8-bit macro-cell")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// Compile an ensemble into a [`CamProgram`].
///
/// Placement (§III-A): trees are grouped by class (so each core is
/// class-uniform, Fig. 7b) and packed round-robin over the minimum number
/// of cores whose 256-word budget fits them. If `options.replicas` > 1 (or
/// 0 = auto) the whole layout is replicated into spare cores for input
/// batching (Fig. 7c).
pub fn compile(model: &Ensemble, options: &CompileOptions) -> Result<CamProgram, CompileError> {
    if model.quantizer.n_bits > 8 {
        return Err(CompileError::PrecisionUnsupported { n_bits: model.quantizer.n_bits });
    }
    let n_bins = model.quantizer.n_bins() as u16;
    let capacity = options.core_rows;

    // Extract rows per tree, grouped by class.
    let k = model.task.n_outputs().max(1);
    let mut class_trees: Vec<Vec<(u32, Vec<CamRow>)>> = vec![Vec::new(); k];
    for (t, tree) in model.trees.iter().enumerate() {
        let class = model.tree_class[t];
        let rows = extract_rows(tree, model.n_features, n_bins, class, t as u32);
        if rows.len() > capacity {
            return Err(CompileError::TreeTooLarge {
                tree: t as u32,
                leaves: rows.len(),
                capacity,
            });
        }
        class_trees[class as usize].push((t as u32, rows));
    }

    // Per class: round-robin packing over the minimal core count.
    let mut cores: Vec<CoreImage> = Vec::new();
    for (class, trees) in class_trees.iter().enumerate() {
        cores.extend(pack_class_cores(class as u16, trees, capacity));
    }

    let model_cores = cores.len();
    if model_cores > options.chip_cores {
        return Err(CompileError::ChipOverflow {
            needed: model_cores,
            available: options.chip_cores,
        });
    }

    // Replication for batching.
    let max_replicas = (options.chip_cores / model_cores).max(1);
    let n_replicas = match options.replicas {
        0 => max_replicas,
        r => r.min(max_replicas),
    };

    let noc = NocConfig::build(&cores, n_replicas, options.chip_cores);

    let mut program = CamProgram {
        name: model.name.clone(),
        task: model.task,
        n_features: model.n_features,
        n_bins,
        n_bits: model.quantizer.n_bits,
        base_score: model.base_score.clone(),
        cores,
        n_replicas,
        noc,
        quantizer: model.quantizer.clone(),
        n_trees: model.n_trees(),
        layouts: None,
    };
    if options.compress {
        compress_program(&mut program);
    }
    Ok(program)
}

/// Post-training quantization: remap a trained ensemble onto the
/// `deploy_bits` grid derived from its own quantizer
/// ([`FeatureQuantizer::coarsen`]), recording per-threshold snap fidelity
/// in the returned [`HatReport`].
///
/// * A model already at (or below) `deploy_bits` — notably anything from
///   `trees::hat::train` — round-trips **losslessly**: the coarse grid's
///   cuts are a subset of its own, so every threshold maps exactly.
/// * A higher-precision model (e.g. the 11-bit "unconstrained" baseline)
///   gets the classic lossy PTQ treatment whose accuracy cliff Fig. 9a
///   measures; the report quantifies the displacement.
pub fn requantize(model: &Ensemble, deploy_bits: u8) -> (Ensemble, HatReport) {
    assert!(deploy_bits >= 1, "deploy grid needs at least 1 bit");
    if model.quantizer.n_bits <= deploy_bits {
        // Already representable on the deployment grid: identity.
        let n: usize = model
            .trees
            .iter()
            .map(|t| t.nodes.iter().filter(|n| matches!(n, Node::Split { .. })).count())
            .sum();
        let report = HatReport {
            deploy_bits: model.quantizer.n_bits,
            n_thresholds: n,
            n_exact: n,
            ..Default::default()
        };
        return (model.clone(), report);
    }
    let grid = model.quantizer.coarsen(deploy_bits);
    let mut report = HatReport { deploy_bits, ..Default::default() };
    let trees =
        model.trees.iter().map(|t| snap_tree(t, &model.quantizer, &grid, &mut report)).collect();
    let snapped = Ensemble {
        name: model.name.clone(),
        task: model.task,
        n_features: model.n_features,
        trees,
        tree_class: model.tree_class.clone(),
        base_score: model.base_score.clone(),
        quantizer: grid,
    };
    (snapped, report)
}

/// Compile for an n-bit deployment: [`requantize`] onto the deployment
/// grid (a no-op for models already on it), then [`compile`]. Returns the
/// program together with the snap-fidelity [`HatReport`] — callers
/// deploying hardware-aware-trained models assert
/// [`HatReport::assert_lossless`] (DESIGN.md §5, contract 5); callers
/// deploying post-training-quantized models read the loss they accepted.
///
/// `deploy_bits` is the hardware precision *ceiling*: a model trained on
/// a coarser grid deploys on its own grid unchanged (the CAM's finer
/// levels trivially represent it), and `HatReport::deploy_bits` /
/// `CamProgram::n_bins` report that **effective** grid — check the
/// report, not the requested ceiling, when asserting precision.
pub fn compile_for_deploy(
    model: &Ensemble,
    deploy_bits: u8,
    options: &CompileOptions,
) -> Result<(CamProgram, HatReport), CompileError> {
    if deploy_bits == 0 || deploy_bits > 8 {
        return Err(CompileError::PrecisionUnsupported { n_bits: deploy_bits });
    }
    let (snapped, report) = requantize(model, deploy_bits);
    let program = compile(&snapped, options)?;
    Ok((program, report))
}

/// Pack one class's trees into the minimum number of class-uniform cores
/// (round-robin with first-fit fallback; grows the core count and repacks
/// when fragmentation blocks a placement). Shared by [`compile`] and the
/// shard partitioner ([`super::partition`]).
///
/// Every tree must individually fit `capacity` (checked by callers).
pub(crate) fn pack_class_cores(
    class: u16,
    trees: &[(u32, Vec<CamRow>)],
    capacity: usize,
) -> Vec<CoreImage> {
    if trees.is_empty() {
        return Vec::new();
    }
    let total: usize = trees.iter().map(|(_, r)| r.len()).sum();
    let mut n_cores = total.div_ceil(capacity).max(1);
    loop {
        let mut imgs: Vec<CoreImage> = (0..n_cores)
            .map(|_| CoreImage { rows: Vec::new(), trees: Vec::new(), class, replica: 0 })
            .collect();
        let mut packed = true;
        'place: for (i, (tid, rows)) in trees.iter().enumerate() {
            // Round-robin with first-fit fallback.
            let start = i % n_cores;
            for off in 0..n_cores {
                let c = (start + off) % n_cores;
                if imgs[c].rows.len() + rows.len() <= capacity {
                    imgs[c].rows.extend(rows.iter().cloned());
                    imgs[c].trees.push(*tid);
                    continue 'place;
                }
            }
            // Fragmentation: grow the core count and repack.
            n_cores += 1;
            packed = false;
            break;
        }
        if packed {
            return imgs;
        }
    }
}

impl CamProgram {
    /// Cores used by one replica.
    pub fn cores_per_replica(&self) -> usize {
        self.cores.len()
    }

    /// Total cores used on chip (all replicas).
    pub fn total_cores(&self) -> usize {
        self.cores.len() * self.n_replicas
    }

    /// Max trees mapped to any single core (drives pipeline bubbles, Eq. 5).
    pub fn max_trees_per_core(&self) -> usize {
        self.cores.iter().map(|c| c.n_trees_core()).max().unwrap_or(0)
    }

    /// Total CAM rows (≈ total ensemble leaves).
    pub fn total_rows(&self) -> usize {
        self.cores.iter().map(|c| c.rows.len()).sum()
    }

    /// Physical CAM words core `ci` occupies: its compressed layout's
    /// word count when present, else one word per logical row.
    pub fn phys_rows(&self, ci: usize) -> usize {
        match &self.layouts {
            Some(layouts) => layouts[ci].n_phys_rows(),
            None => self.cores[ci].rows.len(),
        }
    }

    /// Total physical CAM words across the program (one replica).
    pub fn total_phys_rows(&self) -> usize {
        (0..self.cores.len()).map(|ci| self.phys_rows(ci)).sum()
    }

    // ---- serialization ---------------------------------------------------
    //
    // The encoding is *canonical*: every float uses the bit-exact
    // `Json::canon_f32` form and `from_json(to_json(p))` reproduces `p`
    // including its NoC configuration, so encoding the same program twice
    // — or re-encoding a decoded one — yields byte-identical text. The
    // artifact store (`crate::artifact`) digests these bytes; any
    // encode-cycle instability would make digests drift.

    pub fn to_json(&self) -> Json {
        let mut cores = Vec::with_capacity(self.cores.len());
        for c in &self.cores {
            let mut lo = Vec::new();
            let mut hi = Vec::new();
            let mut leaf = Vec::new();
            let mut class = Vec::new();
            let mut tree = Vec::new();
            for r in &c.rows {
                lo.extend(r.lo.iter().map(|&v| Json::Num(v as f64)));
                hi.extend(r.hi.iter().map(|&v| Json::Num(v as f64)));
                leaf.push(Json::canon_f32(r.leaf));
                class.push(Json::Num(r.class as f64));
                tree.push(Json::Num(r.tree as f64));
            }
            let mut o = Json::obj();
            o.set("lo", Json::Arr(lo))
                .set("hi", Json::Arr(hi))
                .set("leaf", Json::Arr(leaf))
                .set("class", Json::Arr(class))
                .set("tree", Json::Arr(tree))
                .set("trees", Json::from_usize_slice(
                    &c.trees.iter().map(|&t| t as usize).collect::<Vec<_>>(),
                ))
                .set("core_class", Json::Num(c.class as f64))
                .set("replica", Json::Num(c.replica as f64));
            cores.push(o);
        }
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("task", Json::Str(self.task.name()))
            .set("n_classes", Json::Num(self.task.n_classes() as f64))
            .set("n_features", Json::Num(self.n_features as f64))
            .set("n_bins", Json::Num(self.n_bins as f64))
            .set("n_bits", Json::Num(self.n_bits as f64))
            .set("n_trees", Json::Num(self.n_trees as f64))
            .set("n_replicas", Json::Num(self.n_replicas as f64))
            // The slot capacity the NoC was built against. `NocConfig::build`
            // is deterministic in (cores, n_replicas, chip budget), so
            // carrying this one number lets the decoder rebuild the exact
            // tree even for programs compiled with a non-default
            // `CompileOptions::chip_cores`.
            .set("noc_slots", Json::Num(self.noc.n_slots as f64))
            .set("base_score", Json::from_canon_f32_slice(&self.base_score))
            .set("cores", Json::Arr(cores))
            .set("quantizer", self.quantizer.to_json());
        // Emitted only when present: uncompressed programs keep their
        // pre-compression byte encoding (and therefore their digests).
        if let Some(layouts) = &self.layouts {
            o.set("layouts", Json::Arr(layouts.iter().map(|l| l.to_json()).collect()));
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<CamProgram, String> {
        let task = Task::from_name(j.req_str("task")?, j.req_usize("n_classes")?)?;
        let n_features = j.req_usize("n_features")?;
        if n_features == 0 {
            return Err("program has zero features".into());
        }
        let mut cores = Vec::new();
        for (ci, cj) in j.req_arr("cores")?.iter().enumerate() {
            let lo = cj.req("lo")?.f64_vec()?;
            let hi = cj.req("hi")?.f64_vec()?;
            let leaf = cj.req("leaf")?.canon_f32_vec()?;
            let class = cj.req("class")?.usize_vec()?;
            let tree = cj.req("tree")?.usize_vec()?;
            let n_rows = leaf.len();
            // A corrupt or truncated file must come back as an error,
            // never a slice panic.
            if lo.len() != n_rows * n_features
                || hi.len() != n_rows * n_features
                || class.len() != n_rows
                || tree.len() != n_rows
            {
                return Err(format!(
                    "core {ci}: row arrays disagree ({} leaves, lo {}, hi {}, class {}, tree {} \
                     for {n_features} features)",
                    n_rows,
                    lo.len(),
                    hi.len(),
                    class.len(),
                    tree.len()
                ));
            }
            let mut rows = Vec::with_capacity(n_rows);
            for r in 0..n_rows {
                rows.push(CamRow {
                    lo: lo[r * n_features..(r + 1) * n_features].iter().map(|&v| v as u16).collect(),
                    hi: hi[r * n_features..(r + 1) * n_features].iter().map(|&v| v as u16).collect(),
                    leaf: leaf[r],
                    class: class[r] as u16,
                    tree: tree[r] as u32,
                });
            }
            cores.push(CoreImage {
                rows,
                trees: cj.req("trees")?.usize_vec()?.into_iter().map(|t| t as u32).collect(),
                class: cj.req_usize("core_class")? as u16,
                replica: cj.req_usize("replica")? as u32,
            });
        }
        let layouts = match j.get("layouts") {
            Some(lj) => {
                let arr = lj.as_arr().ok_or("field `layouts` is not an array")?;
                if arr.len() != cores.len() {
                    return Err(format!(
                        "{} compression layouts for {} cores",
                        arr.len(),
                        cores.len()
                    ));
                }
                Some(
                    arr.iter()
                        .enumerate()
                        .map(|(ci, l)| {
                            CoreLayout::from_json(l, ci, cores[ci].rows.len(), n_features)
                        })
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            None => None,
        };
        let n_replicas = j.req_usize("n_replicas")?;
        if n_replicas == 0 {
            return Err("program has zero replicas".into());
        }
        // Rebuild the NoC deterministically for the recorded slot budget.
        // Files from before the `noc_slots` field assume the paper chip.
        let noc_slots = match j.get("noc_slots") {
            Some(s) => s.as_usize().ok_or("field `noc_slots` is not a number")?,
            None => CHIP_CORES,
        };
        let noc = NocConfig::build(&cores, n_replicas, noc_slots);
        let quantizer = match j.get("quantizer") {
            Some(q) => FeatureQuantizer::from_json(q)?,
            // Pre-artifact files carried the quantizer as two flat fields.
            None => FeatureQuantizer {
                n_bits: j.req_usize("quant_bits")? as u8,
                edges: j
                    .req_arr("quant_edges")?
                    .iter()
                    .map(|e| e.f32_vec())
                    .collect::<Result<Vec<_>, _>>()?,
            },
        };
        Ok(CamProgram {
            name: j.req_str("name")?.to_string(),
            task,
            n_features,
            n_bins: j.req_usize("n_bins")? as u16,
            n_bits: j.req_usize("n_bits")? as u8,
            base_score: j.req("base_score")?.canon_f32_vec()?,
            cores,
            n_replicas,
            noc,
            quantizer,
            n_trees: j.req_usize("n_trees")?,
            layouts,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &std::path::Path) -> Result<CamProgram, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        CamProgram::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn small_model() -> Ensemble {
        let d = by_name("churn").unwrap().generate_n(1200);
        gbdt::train(
            &d,
            &GbdtParams { n_rounds: 12, max_leaves: 16, ..Default::default() },
            None,
        )
    }

    #[test]
    fn compiles_within_capacity() {
        let m = small_model();
        let p = compile(&m, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_trees, 12);
        assert!(p.cores.iter().all(|c| c.rows.len() <= CORE_ROWS));
        // 12 trees × ≤16 leaves = ≤192 rows → fits one core.
        assert_eq!(p.cores_per_replica(), 1);
        assert_eq!(p.total_rows(), m.total_leaves());
    }

    #[test]
    fn small_core_forces_spill() {
        let m = small_model();
        let opts = CompileOptions { core_rows: 32, ..Default::default() };
        let p = compile(&m, &opts).unwrap();
        assert!(p.cores_per_replica() > 1);
        assert!(p.cores.iter().all(|c| c.rows.len() <= 32));
        assert_eq!(p.total_rows(), m.total_leaves());
    }

    #[test]
    fn tree_too_large_rejected() {
        let d = by_name("churn").unwrap().generate_n(3000);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 1, max_leaves: 64, max_depth: 16, ..Default::default() },
            None,
        );
        let opts = CompileOptions { core_rows: 8, ..Default::default() };
        match compile(&m, &opts) {
            Err(CompileError::TreeTooLarge { capacity: 8, .. }) => {}
            other => panic!("expected TreeTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn chip_overflow_rejected() {
        let m = small_model();
        let opts = CompileOptions { core_rows: 16, chip_cores: 2, ..Default::default() };
        assert!(matches!(compile(&m, &opts), Err(CompileError::ChipOverflow { .. })));
    }

    #[test]
    fn cores_are_class_uniform() {
        let d = by_name("eye").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 10, max_leaves: 32, ..Default::default() },
            None,
        );
        let opts = CompileOptions { core_rows: 64, ..Default::default() };
        let p = compile(&m, &opts).unwrap();
        for c in &p.cores {
            assert!(c.rows.iter().all(|r| r.class == c.class));
        }
        // All three classes present.
        let mut classes: Vec<u16> = p.cores.iter().map(|c| c.class).collect();
        classes.sort_unstable();
        classes.dedup();
        assert_eq!(classes, vec![0, 1, 2]);
    }

    #[test]
    fn auto_replication_fills_chip() {
        let m = small_model();
        let opts = CompileOptions { replicas: 0, chip_cores: 64, ..Default::default() };
        let p = compile(&m, &opts).unwrap();
        assert_eq!(p.cores_per_replica(), 1);
        assert_eq!(p.n_replicas, 64);
    }

    #[test]
    fn json_roundtrip() {
        let m = small_model();
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let back = CamProgram::from_json(&p.to_json()).unwrap();
        assert_eq!(back.n_trees, p.n_trees);
        assert_eq!(back.cores.len(), p.cores.len());
        for (a, b) in p.cores.iter().zip(&back.cores) {
            assert_eq!(a.rows, b.rows);
            assert_eq!(a.trees, b.trees);
        }
        assert_eq!(back.base_score, p.base_score);
    }

    /// The artifact-store contract: encoding is canonical (re-encoding a
    /// decoded program is byte-identical — stable digests) and the NoC
    /// rebuild is exact, including for non-default chip budgets where
    /// the old decoder's hardcoded `CHIP_CORES` diverged.
    #[test]
    fn json_codec_is_canonical_and_rebuilds_noc_exactly() {
        let m = small_model();
        for chip_cores in [64usize, CHIP_CORES] {
            let opts = CompileOptions { core_rows: 32, chip_cores, ..Default::default() };
            let p = compile(&m, &opts).unwrap();
            let text = p.to_json().to_string();
            let back = CamProgram::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string(), text, "chip_cores {chip_cores}");
            assert_eq!(back.noc.n_slots, p.noc.n_slots, "chip_cores {chip_cores}");
            assert_eq!(back.noc.routers, p.noc.routers, "chip_cores {chip_cores}");
            assert_eq!(back.noc.slot_group, p.noc.slot_group);
            assert_eq!(back.quantizer.n_bits, p.quantizer.n_bits);
            assert_eq!(back.quantizer.edges, p.quantizer.edges);
            for (a, b) in p.base_score.iter().zip(&back.base_score) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// Compression layouts are an *optional* field: opting out keeps the
    /// pre-compression byte encoding (stable digests), opting in is
    /// canonical too, and the logical rows are identical either way
    /// (contract 11).
    #[test]
    fn compressed_codec_is_canonical_and_optional() {
        let m = small_model();
        let plain = compile(&m, &CompileOptions::default()).unwrap();
        let pressed = compile(&m, &CompileOptions { compress: true, ..Default::default() }).unwrap();
        assert!(plain.layouts.is_none());
        assert!(!plain.to_json().to_string().contains("\"layouts\""));
        assert!(pressed.layouts.is_some());
        assert!(pressed.total_phys_rows() < pressed.total_rows());
        let text = pressed.to_json().to_string();
        let back = CamProgram::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.layouts, pressed.layouts);
        for (a, b) in plain.cores.iter().zip(&pressed.cores) {
            assert_eq!(a.rows, b.rows);
            assert_eq!(a.trees, b.trees);
        }
    }

    /// A layouts array that disagrees with the core count is a structured
    /// decode error, never a panic.
    #[test]
    fn json_rejects_layout_core_mismatch() {
        let m = small_model();
        let p = compile(&m, &CompileOptions { compress: true, ..Default::default() }).unwrap();
        let mut j = p.to_json();
        if let Some(Json::Arr(layouts)) = j.get("layouts").cloned() {
            let mut doubled = layouts.clone();
            doubled.extend(layouts);
            j.set("layouts", Json::Arr(doubled));
        }
        let err = CamProgram::from_json(&j).unwrap_err();
        assert!(err.contains("compression layouts"), "{err}");
    }

    /// Pre-artifact program files (flat `quant_bits`/`quant_edges`, no
    /// `noc_slots`) still decode.
    #[test]
    fn json_decodes_legacy_quantizer_fields() {
        let m = small_model();
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let mut j = p.to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("quantizer");
            map.remove("noc_slots");
        }
        j.set("quant_bits", Json::Num(p.quantizer.n_bits as f64)).set(
            "quant_edges",
            Json::Arr(p.quantizer.edges.iter().map(|e| Json::from_f32_slice(e)).collect()),
        );
        let back = CamProgram::from_json(&j).unwrap();
        assert_eq!(back.quantizer.edges, p.quantizer.edges);
        assert_eq!(back.noc.n_slots, p.noc.n_slots);
    }

    /// Corrupt row arrays surface as errors, never slice panics.
    #[test]
    fn json_rejects_inconsistent_row_arrays() {
        let m = small_model();
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let mut j = p.to_json();
        // Truncate core 0's `lo` array.
        if let Some(Json::Arr(cores)) = j.get("cores").cloned() {
            let mut c0 = cores[0].clone();
            if let Some(Json::Arr(lo)) = c0.get("lo").cloned() {
                c0.set("lo", Json::Arr(lo[..lo.len() - 1].to_vec()));
            }
            let mut new_cores = cores.clone();
            new_cores[0] = c0;
            j.set("cores", Json::Arr(new_cores));
        }
        let err = CamProgram::from_json(&j).unwrap_err();
        assert!(err.contains("core 0"), "{err}");
    }

    #[test]
    fn requantize_is_identity_for_hat_models() {
        // A model trained on the 4-bit deploy grid (hardware-aware
        // training) must requantize losslessly and tree-identically.
        let d = by_name("telco").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, n_bits: 4, ..Default::default() },
            None,
        );
        let (snapped, report) = requantize(&m, 4);
        assert!(report.lossless(), "{report:?}");
        assert!(report.n_thresholds > 0, "model has no splits to check");
        assert_eq!(snapped.trees, m.trees);
        assert_eq!(snapped.quantizer.edges, m.quantizer.edges);
        report.assert_lossless("hat identity");
    }

    #[test]
    fn requantize_snaps_high_precision_models_lossily() {
        // 11-bit ≈ float thresholds onto the 4-bit grid: the classic PTQ
        // cliff. With dozens of splits over a 2047-cut grid snapped onto
        // 15 cuts, off-grid thresholds are certain.
        let d = by_name("churn").unwrap().generate_n(2000);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 10, max_leaves: 32, n_bits: 11, ..Default::default() },
            None,
        );
        let (snapped, report) = requantize(&m, 4);
        assert_eq!(report.deploy_bits, 4);
        assert_eq!(snapped.quantizer.n_bits, 4);
        assert!(report.n_thresholds > 50, "want a meaningful threshold count");
        assert!(!report.lossless(), "11→4-bit PTQ cannot be lossless: {report:?}");
        assert!(report.max_snap_err > 0.0);
        assert!(report.mean_snap_err() > 0.0);
        // Thresholds stay inside the coarse grid's bin range.
        let nb = snapped.quantizer.n_bins() as u16;
        for t in &snapped.trees {
            for node in &t.nodes {
                if let Node::Split { threshold_bin, .. } = node {
                    assert!(*threshold_bin >= 1 && *threshold_bin < nb);
                }
            }
        }
        // The snapped model still compiles and predicts sanely.
        let p = compile(&snapped, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_bins, 16);
    }

    #[test]
    fn compile_for_deploy_reports_and_compiles() {
        let d = by_name("churn").unwrap().generate_n(1200);
        // HAT path: trained at 4 bits, deployed at 4 bits — lossless.
        let hat = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, n_bits: 4, ..Default::default() },
            None,
        );
        let (p, report) = compile_for_deploy(&hat, 4, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_bins, 16);
        report.assert_lossless("compile_for_deploy(hat)");
        // PTQ path: trained at 11 bits, deployed at 4 — compiles, lossy.
        let uncon = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, n_bits: 11, ..Default::default() },
            None,
        );
        let (p, report) = compile_for_deploy(&uncon, 4, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_bins, 16);
        assert!(!report.lossless());
        // A coarser model under a finer ceiling deploys on its own
        // *effective* grid: report/program say 4-bit, not the ceiling.
        let (p, report) = compile_for_deploy(&hat, 8, &CompileOptions::default()).unwrap();
        assert_eq!(p.n_bins, 16);
        assert_eq!(report.deploy_bits, 4);
        assert!(report.lossless());
        // Guard: out-of-range deployments are errors, not panics.
        assert!(matches!(
            compile_for_deploy(&uncon, 11, &CompileOptions::default()),
            Err(CompileError::PrecisionUnsupported { n_bits: 11 })
        ));
        assert!(matches!(
            compile_for_deploy(&uncon, 0, &CompileOptions::default()),
            Err(CompileError::PrecisionUnsupported { n_bits: 0 })
        ));
    }

    #[test]
    fn precision_guard() {
        let d = by_name("telco").unwrap().generate_n(600);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 2, max_leaves: 4, n_bits: 11, ..Default::default() },
            None,
        );
        assert!(matches!(
            compile(&m, &CompileOptions::default()),
            Err(CompileError::PrecisionUnsupported { n_bits: 11 })
        ));
    }
}
