//! Root-to-leaf path extraction: trees → CAM rows (paper §II-D, Fig. 3).
//!
//! Every root-to-leaf path of a decision tree becomes one CAM row. Walking
//! down the tree, each comparison `bin(f) >= t` narrows the feature's
//! interval: going left imposes `bin < t` (upper bound), going right
//! imposes `bin >= t` (lower bound). Features never tested on the path
//! keep the full "don't care" range.

use crate::trees::{Node, Tree};

/// One CAM row: per-feature half-open windows `[lo, hi)` in bin space plus
/// the leaf payload stored in the core's SRAM (§III-A: "leaf value, class
/// ID/label and tree ID").
#[derive(Clone, Debug, PartialEq)]
pub struct CamRow {
    pub lo: Vec<u16>,
    pub hi: Vec<u16>,
    pub leaf: f32,
    pub class: u16,
    pub tree: u32,
}

impl CamRow {
    /// Ideal row match: the query bin vector falls in every window.
    #[inline]
    pub fn matches(&self, bins: &[u16]) -> bool {
        debug_assert_eq!(bins.len(), self.lo.len());
        self.lo
            .iter()
            .zip(&self.hi)
            .zip(bins)
            .all(|((&lo, &hi), &q)| lo <= q && q < hi)
    }

    /// Number of non-don't-care cells (path length; equals tree depth of
    /// this leaf at most, since repeated features merge into one window).
    pub fn n_constrained(&self, n_bins: u16) -> usize {
        self.lo
            .iter()
            .zip(&self.hi)
            .filter(|&(&lo, &hi)| lo != 0 || hi < n_bins)
            .count()
    }
}

/// Extract all root-to-leaf paths of `tree` as CAM rows.
///
/// `n_bins` is the quantizer's bin count (`2^n_bits`); windows span
/// `[0, n_bins)` when unconstrained.
pub fn extract_rows(tree: &Tree, n_features: usize, n_bins: u16, class: u16, tree_id: u32) -> Vec<CamRow> {
    let mut rows = Vec::with_capacity(tree.n_leaves());
    let mut lo = vec![0u16; n_features];
    let mut hi = vec![n_bins; n_features];
    walk(tree, 0, &mut lo, &mut hi, n_bins, class, tree_id, &mut rows);
    rows
}

#[allow(clippy::too_many_arguments)]
fn walk(
    tree: &Tree,
    node: u32,
    lo: &mut [u16],
    hi: &mut [u16],
    n_bins: u16,
    class: u16,
    tree_id: u32,
    rows: &mut Vec<CamRow>,
) {
    match tree.nodes[node as usize] {
        Node::Leaf { value } => rows.push(CamRow {
            lo: lo.to_vec(),
            hi: hi.to_vec(),
            leaf: value,
            class,
            tree: tree_id,
        }),
        Node::Split { feature, threshold_bin, left, right } => {
            let f = feature as usize;
            // Left: bin < t → tighten upper bound.
            let saved_hi = hi[f];
            hi[f] = hi[f].min(threshold_bin);
            if lo[f] < hi[f] {
                walk(tree, left, lo, hi, n_bins, class, tree_id, rows);
            }
            hi[f] = saved_hi;
            // Right: bin >= t → tighten lower bound.
            let saved_lo = lo[f];
            lo[f] = lo[f].max(threshold_bin);
            if lo[f] < hi[f] {
                walk(tree, right, lo, hi, n_bins, class, tree_id, rows);
            }
            lo[f] = saved_lo;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trees::Node;
    use crate::util::prop;

    fn sample_tree() -> Tree {
        // f0 >= 3 ? (f1 >= 7 ? 3.0 : 2.0) : 1.0   (Fig. 1a/Fig. 3 style)
        Tree {
            nodes: vec![
                Node::Split { feature: 0, threshold_bin: 3, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Split { feature: 1, threshold_bin: 7, left: 3, right: 4 },
                Node::Leaf { value: 2.0 },
                Node::Leaf { value: 3.0 },
            ],
        }
    }

    #[test]
    fn row_per_leaf_with_correct_windows() {
        let rows = extract_rows(&sample_tree(), 2, 16, 5, 9);
        assert_eq!(rows.len(), 3);
        // Leaf 1.0: f0 ∈ [0,3), f1 don't care.
        assert_eq!(rows[0].lo, vec![0, 0]);
        assert_eq!(rows[0].hi, vec![3, 16]);
        assert_eq!(rows[0].leaf, 1.0);
        // Leaf 2.0: f0 ∈ [3,16), f1 ∈ [0,7).
        assert_eq!(rows[1].lo, vec![3, 0]);
        assert_eq!(rows[1].hi, vec![16, 7]);
        // Leaf 3.0: f0 ∈ [3,16), f1 ∈ [7,16).
        assert_eq!(rows[2].lo, vec![3, 7]);
        assert_eq!(rows[2].hi, vec![16, 16]);
        assert!(rows.iter().all(|r| r.class == 5 && r.tree == 9));
    }

    #[test]
    fn repeated_feature_windows_intersect() {
        // f0>=4 then f0>=8 on the right branch: rightmost leaf window is
        // [8,16), middle is [4,8).
        let t = Tree {
            nodes: vec![
                Node::Split { feature: 0, threshold_bin: 4, left: 1, right: 2 },
                Node::Leaf { value: 0.0 },
                Node::Split { feature: 0, threshold_bin: 8, left: 3, right: 4 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        };
        let rows = extract_rows(&t, 1, 16, 0, 0);
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[1].lo[0], rows[1].hi[0]), (4, 8));
        assert_eq!((rows[2].lo[0], rows[2].hi[0]), (8, 16));
    }

    /// The fundamental mapping theorem (§II-D): for any query, exactly one
    /// row matches per tree, and it carries the tree's predicted leaf.
    #[test]
    fn exactly_one_row_matches_and_agrees() {
        prop::check(300, 0x9A75_1234, |g| {
            // Random tree via the grower on random data.
            use crate::trees::grow::{grow_tree, BinnedMatrix, GrowParams, GrowScratch};
            let n = 64;
            let n_features = g.usize_in(1, 6);
            let n_bins = 16usize;
            let bins: Vec<u16> =
                (0..n * n_features).map(|_| g.usize_in(0, n_bins) as u16).collect();
            let gvec: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let h = vec![1.0f32; n];
            let m = BinnedMatrix { bins, n_rows: n, n_features, n_bins };
            let p = GrowParams { max_leaves: 8, lambda: 0.0, leaf_scale: 1.0, ..Default::default() };
            let mut scratch = GrowScratch::new(n_features, n_bins);
            let tree =
                grow_tree(&m, (0..n as u32).collect(), &gvec, &h, &p, g.rng(), &mut scratch);

            let rows = extract_rows(&tree, n_features, n_bins as u16, 0, 0);
            prop::require(rows.len() == tree.n_leaves(), "row count == leaf count")?;

            let q: Vec<u16> = (0..n_features).map(|_| g.usize_in(0, n_bins) as u16).collect();
            let matched: Vec<&CamRow> = rows.iter().filter(|r| r.matches(&q)).collect();
            prop::require(matched.len() == 1, format!("matched {} rows", matched.len()))?;
            prop::require(
                matched[0].leaf == tree.predict_bins(&q),
                format!("leaf {} != predict {}", matched[0].leaf, tree.predict_bins(&q)),
            )
        });
    }

    #[test]
    fn constrained_cell_count() {
        let rows = extract_rows(&sample_tree(), 2, 16, 0, 0);
        assert_eq!(rows[0].n_constrained(16), 1); // only f0
        assert_eq!(rows[1].n_constrained(16), 2);
    }
}
