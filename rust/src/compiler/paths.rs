//! Root-to-leaf path extraction: trees → CAM rows (paper §II-D, Fig. 3).
//!
//! Every root-to-leaf path of a decision tree becomes one CAM row. Walking
//! down the tree, each comparison `bin(f) >= t` narrows the feature's
//! interval: going left imposes `bin < t` (upper bound), going right
//! imposes `bin >= t` (lower bound). Features never tested on the path
//! keep the full "don't care" range.

use crate::data::FeatureQuantizer;
use crate::trees::{Node, Tree};

/// One CAM row: per-feature half-open windows `[lo, hi)` in bin space plus
/// the leaf payload stored in the core's SRAM (§III-A: "leaf value, class
/// ID/label and tree ID").
#[derive(Clone, Debug, PartialEq)]
pub struct CamRow {
    pub lo: Vec<u16>,
    pub hi: Vec<u16>,
    pub leaf: f32,
    pub class: u16,
    pub tree: u32,
}

impl CamRow {
    /// Ideal row match: the query bin vector falls in every window.
    #[inline]
    pub fn matches(&self, bins: &[u16]) -> bool {
        debug_assert_eq!(bins.len(), self.lo.len());
        self.lo
            .iter()
            .zip(&self.hi)
            .zip(bins)
            .all(|((&lo, &hi), &q)| lo <= q && q < hi)
    }

    /// Number of non-don't-care cells (path length; equals tree depth of
    /// this leaf at most, since repeated features merge into one window).
    pub fn n_constrained(&self, n_bins: u16) -> usize {
        self.lo
            .iter()
            .zip(&self.hi)
            .filter(|&(&lo, &hi)| lo != 0 || hi < n_bins)
            .count()
    }
}

/// Fidelity report of mapping a model's split thresholds onto a
/// deployment grid (DESIGN.md §5, contract 5). Produced by
/// [`crate::compiler::requantize`] / [`crate::compiler::compile_for_deploy`].
///
/// A hardware-aware-trained model (`trees::hat`) already lives on the
/// deployment grid, so every threshold maps exactly (`lossless()`); a
/// post-training-quantized high-precision model generally does not — the
/// per-threshold displacement recorded here is precisely the Fig. 9a
/// low-precision accuracy loss.
#[derive(Clone, Debug, Default)]
pub struct HatReport {
    /// Precision of the deployment grid actually used.
    pub deploy_bits: u8,
    /// Split thresholds examined across the ensemble.
    pub n_thresholds: usize,
    /// Thresholds that landed exactly on a deployment-grid cut.
    pub n_exact: usize,
    /// Largest |raw threshold − snapped grid cut| in raw feature units.
    pub max_snap_err: f32,
    /// Sum of absolute snap errors (see [`HatReport::mean_snap_err`]).
    pub sum_snap_err: f64,
}

impl HatReport {
    /// True iff every threshold mapped onto the grid with zero error —
    /// the hardware-aware-training deployment contract.
    pub fn lossless(&self) -> bool {
        self.n_exact == self.n_thresholds
    }

    /// Mean absolute snap error in raw feature units.
    pub fn mean_snap_err(&self) -> f32 {
        if self.n_thresholds == 0 {
            0.0
        } else {
            (self.sum_snap_err / self.n_thresholds as f64) as f32
        }
    }

    /// Contract 5: hardware-aware-trained models must deploy losslessly.
    /// Panics with the offending statistics otherwise.
    pub fn assert_lossless(&self, context: &str) {
        assert!(
            self.lossless(),
            "{context}: threshold snapping lost precision — {}/{} thresholds off-grid \
             (max err {}, mean err {}); HAT-trained models must map losslessly \
             (DESIGN.md §5 contract 5)",
            self.n_thresholds - self.n_exact,
            self.n_thresholds,
            self.max_snap_err,
            self.mean_snap_err()
        );
    }
}

/// Snap one fine-grid threshold onto the deployment grid: the coarse cut
/// nearest to the threshold's raw cut value wins (ties resolve to the
/// lower cut). Returns the coarse threshold bin and the absolute snap
/// error in raw feature units — 0.0 exactly when the threshold already
/// lies on the deployment grid, which [`FeatureQuantizer::coarsen`]
/// guarantees for grids derived from the model's own (cut subsets).
pub fn snap_threshold(fine_cuts: &[f32], coarse_cuts: &[f32], threshold_bin: u16) -> (u16, f32) {
    if coarse_cuts.is_empty() {
        // The deployment grid has no cut on this feature (constant in
        // training data): the split cannot discriminate post-deploy.
        // Bin 1 sends every query left (all queries bin to 0).
        return (1, 0.0);
    }
    debug_assert!(threshold_bin >= 1, "threshold bins start at 1");
    // A trained threshold bin t corresponds to the fine cut below it;
    // clamp defensively for synthetic trees with out-of-range bins.
    let idx = (threshold_bin as usize - 1).min(fine_cuts.len().saturating_sub(1));
    let Some(&c) = fine_cuts.get(idx) else {
        return (1, 0.0);
    };
    let j = coarse_cuts.partition_point(|&x| x < c);
    let lower = j.checked_sub(1).map(|l| (l, (c - coarse_cuts[l]).abs()));
    let upper = coarse_cuts.get(j).map(|&u| (j, (u - c).abs()));
    let (k, err) = match (lower, upper) {
        (Some((l, dl)), Some((_, du))) if dl <= du => (l, dl),
        (_, Some((u, du))) => (u, du),
        (Some((l, dl)), None) => (l, dl),
        (None, None) => unreachable!("coarse_cuts checked non-empty"),
    };
    ((k + 1) as u16, err)
}

/// Remap every split threshold of `tree` from the `fine` grid onto the
/// `coarse` deployment grid, accumulating fidelity statistics into
/// `report`. Leaves, topology and feature ids are untouched.
pub fn snap_tree(
    tree: &Tree,
    fine: &FeatureQuantizer,
    coarse: &FeatureQuantizer,
    report: &mut HatReport,
) -> Tree {
    let nodes = tree
        .nodes
        .iter()
        .map(|n| match *n {
            Node::Leaf { value } => Node::Leaf { value },
            Node::Split { feature, threshold_bin, left, right } => {
                let f = feature as usize;
                let (t, err) = snap_threshold(&fine.edges[f], &coarse.edges[f], threshold_bin);
                report.n_thresholds += 1;
                if err == 0.0 {
                    report.n_exact += 1;
                }
                report.max_snap_err = report.max_snap_err.max(err);
                report.sum_snap_err += err as f64;
                Node::Split { feature, threshold_bin: t, left, right }
            }
        })
        .collect();
    Tree { nodes }
}

/// Extract all root-to-leaf paths of `tree` as CAM rows.
///
/// `n_bins` is the quantizer's bin count (`2^n_bits`); windows span
/// `[0, n_bins)` when unconstrained.
pub fn extract_rows(tree: &Tree, n_features: usize, n_bins: u16, class: u16, tree_id: u32) -> Vec<CamRow> {
    let mut rows = Vec::with_capacity(tree.n_leaves());
    let mut lo = vec![0u16; n_features];
    let mut hi = vec![n_bins; n_features];
    walk(tree, 0, &mut lo, &mut hi, n_bins, class, tree_id, &mut rows);
    rows
}

#[allow(clippy::too_many_arguments)]
fn walk(
    tree: &Tree,
    node: u32,
    lo: &mut [u16],
    hi: &mut [u16],
    n_bins: u16,
    class: u16,
    tree_id: u32,
    rows: &mut Vec<CamRow>,
) {
    match tree.nodes[node as usize] {
        Node::Leaf { value } => rows.push(CamRow {
            lo: lo.to_vec(),
            hi: hi.to_vec(),
            leaf: value,
            class,
            tree: tree_id,
        }),
        Node::Split { feature, threshold_bin, left, right } => {
            let f = feature as usize;
            // Left: bin < t → tighten upper bound.
            let saved_hi = hi[f];
            hi[f] = hi[f].min(threshold_bin);
            if lo[f] < hi[f] {
                walk(tree, left, lo, hi, n_bins, class, tree_id, rows);
            }
            hi[f] = saved_hi;
            // Right: bin >= t → tighten lower bound.
            let saved_lo = lo[f];
            lo[f] = lo[f].max(threshold_bin);
            if lo[f] < hi[f] {
                walk(tree, right, lo, hi, n_bins, class, tree_id, rows);
            }
            lo[f] = saved_lo;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trees::Node;
    use crate::util::prop;

    fn sample_tree() -> Tree {
        // f0 >= 3 ? (f1 >= 7 ? 3.0 : 2.0) : 1.0   (Fig. 1a/Fig. 3 style)
        Tree {
            nodes: vec![
                Node::Split { feature: 0, threshold_bin: 3, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Split { feature: 1, threshold_bin: 7, left: 3, right: 4 },
                Node::Leaf { value: 2.0 },
                Node::Leaf { value: 3.0 },
            ],
        }
    }

    #[test]
    fn row_per_leaf_with_correct_windows() {
        let rows = extract_rows(&sample_tree(), 2, 16, 5, 9);
        assert_eq!(rows.len(), 3);
        // Leaf 1.0: f0 ∈ [0,3), f1 don't care.
        assert_eq!(rows[0].lo, vec![0, 0]);
        assert_eq!(rows[0].hi, vec![3, 16]);
        assert_eq!(rows[0].leaf, 1.0);
        // Leaf 2.0: f0 ∈ [3,16), f1 ∈ [0,7).
        assert_eq!(rows[1].lo, vec![3, 0]);
        assert_eq!(rows[1].hi, vec![16, 7]);
        // Leaf 3.0: f0 ∈ [3,16), f1 ∈ [7,16).
        assert_eq!(rows[2].lo, vec![3, 7]);
        assert_eq!(rows[2].hi, vec![16, 16]);
        assert!(rows.iter().all(|r| r.class == 5 && r.tree == 9));
    }

    #[test]
    fn repeated_feature_windows_intersect() {
        // f0>=4 then f0>=8 on the right branch: rightmost leaf window is
        // [8,16), middle is [4,8).
        let t = Tree {
            nodes: vec![
                Node::Split { feature: 0, threshold_bin: 4, left: 1, right: 2 },
                Node::Leaf { value: 0.0 },
                Node::Split { feature: 0, threshold_bin: 8, left: 3, right: 4 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        };
        let rows = extract_rows(&t, 1, 16, 0, 0);
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[1].lo[0], rows[1].hi[0]), (4, 8));
        assert_eq!((rows[2].lo[0], rows[2].hi[0]), (8, 16));
    }

    /// The fundamental mapping theorem (§II-D): for any query, exactly one
    /// row matches per tree, and it carries the tree's predicted leaf.
    #[test]
    fn exactly_one_row_matches_and_agrees() {
        prop::check(300, 0x9A75_1234, |g| {
            // Random tree via the grower on random data.
            use crate::trees::grow::{grow_tree, BinnedMatrix, GrowParams, GrowScratch};
            let n = 64;
            let n_features = g.usize_in(1, 6);
            let n_bins = 16usize;
            let bins: Vec<u16> =
                (0..n * n_features).map(|_| g.usize_in(0, n_bins) as u16).collect();
            let gvec: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let h = vec![1.0f32; n];
            let m = BinnedMatrix { bins, n_rows: n, n_features, n_bins };
            let p = GrowParams { max_leaves: 8, lambda: 0.0, leaf_scale: 1.0, ..Default::default() };
            let mut scratch = GrowScratch::new(n_features, n_bins);
            let tree =
                grow_tree(&m, (0..n as u32).collect(), &gvec, &h, &p, g.rng(), &mut scratch);

            let rows = extract_rows(&tree, n_features, n_bins as u16, 0, 0);
            prop::require(rows.len() == tree.n_leaves(), "row count == leaf count")?;

            let q: Vec<u16> = (0..n_features).map(|_| g.usize_in(0, n_bins) as u16).collect();
            let matched: Vec<&CamRow> = rows.iter().filter(|r| r.matches(&q)).collect();
            prop::require(matched.len() == 1, format!("matched {} rows", matched.len()))?;
            prop::require(
                matched[0].leaf == tree.predict_bins(&q),
                format!("leaf {} != predict {}", matched[0].leaf, tree.predict_bins(&q)),
            )
        });
    }

    #[test]
    fn snap_threshold_picks_nearest_coarse_cut() {
        let fine = [0.1f32, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7];
        let coarse = [0.2f32, 0.5];
        // t=1 → cut 0.1 → nearest 0.2 (coarse bin 1), err 0.1.
        let (t, e) = snap_threshold(&fine, &coarse, 1);
        assert_eq!(t, 1);
        assert!((e - 0.1).abs() < 1e-6);
        // t=4 → cut 0.4 → nearest 0.5 (coarse bin 2), err 0.1.
        let (t, e) = snap_threshold(&fine, &coarse, 4);
        assert_eq!(t, 2);
        assert!((e - 0.1).abs() < 1e-6);
        // t=7 → cut 0.7 → nearest 0.5, err 0.2.
        let (t, e) = snap_threshold(&fine, &coarse, 7);
        assert_eq!(t, 2);
        assert!((e - 0.2).abs() < 1e-6);
        // A threshold already on the grid maps exactly.
        let (t, e) = snap_threshold(&fine, &coarse, 2);
        assert_eq!(t, 1);
        assert_eq!(e, 0.0);
        let (t, e) = snap_threshold(&fine, &coarse, 5);
        assert_eq!(t, 2);
        assert_eq!(e, 0.0);
        // Equidistant ties go to the lower cut: 0.35 is synthetic here,
        // use cut 0.3/0.4 vs grid {0.2, 0.5}: 0.3→0.2 (dl=0.1 ≤ du=0.2).
        let (t, _) = snap_threshold(&fine, &coarse, 3);
        assert_eq!(t, 1);
    }

    #[test]
    fn snap_tree_identity_on_shared_grid() {
        use crate::data::FeatureQuantizer;
        let q = FeatureQuantizer {
            n_bits: 4,
            edges: vec![vec![0.25, 0.5, 0.75], vec![0.1, 0.9]],
        };
        let mut report = HatReport { deploy_bits: 4, ..Default::default() };
        let t2 = Tree {
            nodes: vec![
                Node::Split { feature: 0, threshold_bin: 2, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Split { feature: 1, threshold_bin: 1, left: 3, right: 4 },
                Node::Leaf { value: 2.0 },
                Node::Leaf { value: 3.0 },
            ],
        };
        let snapped = snap_tree(&t2, &q, &q, &mut report);
        assert_eq!(snapped, t2, "same-grid snap must be the identity");
        assert_eq!(report.n_thresholds, 2);
        assert_eq!(report.n_exact, 2);
        assert!(report.lossless());
        assert_eq!(report.max_snap_err, 0.0);
        report.assert_lossless("identity snap");
    }

    #[test]
    #[should_panic(expected = "contract 5")]
    fn assert_lossless_panics_on_lossy_report() {
        let report = HatReport {
            deploy_bits: 4,
            n_thresholds: 10,
            n_exact: 9,
            max_snap_err: 0.05,
            sum_snap_err: 0.05,
        };
        report.assert_lossless("test");
    }

    #[test]
    fn constrained_cell_count() {
        let rows = extract_rows(&sample_tree(), 2, 16, 0, 0);
        assert_eq!(rows[0].n_constrained(16), 1); // only f0
        assert_eq!(rows[1].n_constrained(16), 2);
    }
}
