//! Programmable H-tree NoC configuration (paper §III-D, Fig. 7).
//!
//! The chip connects 4096 cores through a radix-4 H-tree (1365 routers)
//! converging on the co-processor. Each router has one *configuration bit*:
//!
//!  * `1` — accumulate: sum incoming leaf logits into a single flit
//!    (legal only when every core in the router's subtree contributes to
//!    the same class and the same input-batch replica);
//!  * `0` — passthrough: forward distinct logit streams unchanged.
//!
//! The compiler derives the bits from the placement: a router accumulates
//! iff all used cores below it share one `(class, replica)` group. This
//! generalizes all four inference modes of §III-D (regression/binary,
//! multi-class, and both with input batching).

use super::program::CoreImage;

/// A router in the H-tree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Router {
    /// Level above the cores (1 = leaf routers).
    pub level: usize,
    /// First chip slot covered by this router's subtree.
    pub slot_base: usize,
    /// Number of slots covered (`radix^level`).
    pub slot_span: usize,
    /// The configuration bit.
    pub accumulate: bool,
}

/// The configured H-tree.
#[derive(Clone, Debug)]
pub struct NocConfig {
    pub radix: usize,
    /// Chip slots (rounded up to a power of the radix).
    pub n_slots: usize,
    pub levels: usize,
    /// Routers in level-major order (level 1 first).
    pub routers: Vec<Router>,
    /// Group of each chip slot: `(class, replica)` of the core mapped
    /// there, or `None` for unused slots.
    pub slot_group: Vec<Option<(u16, u32)>>,
}

impl NocConfig {
    /// Build the tree for a placement. Replica `r`'s copy of core `i`
    /// occupies chip slot `r * cores_per_replica + i`.
    pub fn build(cores: &[CoreImage], n_replicas: usize, chip_cores: usize) -> NocConfig {
        let radix = 4usize;
        let used = cores.len() * n_replicas;
        let mut n_slots = radix; // at least one router
        let mut levels = 1usize;
        while n_slots < chip_cores.max(used) {
            n_slots *= radix;
            levels += 1;
        }

        let mut slot_group = vec![None; n_slots];
        for r in 0..n_replicas {
            for (i, c) in cores.iter().enumerate() {
                slot_group[r * cores.len() + i] = Some((c.class, r as u32));
            }
        }

        let mut routers = Vec::new();
        for level in 1..=levels {
            let span = radix.pow(level as u32);
            for j in 0..n_slots / span {
                let base = j * span;
                let mut group: Option<(u16, u32)> = None;
                let mut uniform = true;
                for s in base..base + span {
                    if let Some(g) = slot_group[s] {
                        match group {
                            None => group = Some(g),
                            Some(g0) if g0 != g => {
                                uniform = false;
                                break;
                            }
                            _ => {}
                        }
                    }
                }
                routers.push(Router {
                    level,
                    slot_base: base,
                    slot_span: span,
                    accumulate: uniform && group.is_some(),
                });
            }
        }
        NocConfig { radix, n_slots, levels, routers, slot_group }
    }

    pub fn n_routers(&self) -> usize {
        self.routers.len()
    }

    /// Routers whose configuration bit is set.
    pub fn n_accumulating(&self) -> usize {
        self.routers.iter().filter(|r| r.accumulate).count()
    }

    /// Router index covering `slot` at `level` (level-major layout).
    pub fn router_at(&self, level: usize, slot: usize) -> usize {
        debug_assert!((1..=self.levels).contains(&level));
        let mut idx = 0usize;
        for l in 1..level {
            idx += self.n_slots / self.radix.pow(l as u32);
        }
        idx + slot / self.radix.pow(level as u32)
    }

    /// Functional in-network reduction: fold per-slot logit contributions
    /// up the tree honoring the configuration bits; returns the flit
    /// streams arriving at the co-processor as `(class, replica, value)`.
    ///
    /// Used by tests and the cycle simulator to verify that the
    /// configuration never merges logits across classes or batch slots.
    pub fn reduce(&self, slot_values: &[(usize, f32)]) -> Vec<(u16, u32, f32)> {
        // Streams per slot: (class, replica, value).
        let mut streams: Vec<Vec<(u16, u32, f32)>> = vec![Vec::new(); self.n_slots];
        for &(slot, v) in slot_values {
            let (class, replica) =
                self.slot_group[slot].expect("value injected into an unused slot");
            streams[slot].push((class, replica, v));
        }
        let mut width = self.n_slots;
        for level in 1..=self.levels {
            let mut next: Vec<Vec<(u16, u32, f32)>> = vec![Vec::new(); width / self.radix];
            for (j, bucket) in next.iter_mut().enumerate() {
                let r = &self.routers[self.router_at(level, j * self.radix.pow(level as u32))];
                let mut merged: Vec<(u16, u32, f32)> = Vec::new();
                for c in 0..self.radix {
                    merged.extend(streams[j * self.radix + c].iter().copied());
                }
                if r.accumulate && !merged.is_empty() {
                    let (class, replica, _) = merged[0];
                    debug_assert!(
                        merged.iter().all(|&(c, rep, _)| c == class && rep == replica),
                        "accumulating router with mixed groups"
                    );
                    let sum: f32 = merged.iter().map(|&(_, _, v)| v).sum();
                    bucket.push((class, replica, sum));
                } else {
                    *bucket = merged;
                }
            }
            streams = next;
            width /= self.radix;
        }
        streams.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::paths::CamRow;

    fn core(class: u16) -> CoreImage {
        CoreImage {
            rows: vec![CamRow { lo: vec![0], hi: vec![16], leaf: 1.0, class, tree: 0 }],
            trees: vec![0],
            class,
            replica: 0,
        }
    }

    #[test]
    fn paper_chip_has_1365_routers() {
        let cores: Vec<CoreImage> = (0..8).map(|_| core(0)).collect();
        let noc = NocConfig::build(&cores, 1, 4096);
        assert_eq!(noc.n_slots, 4096);
        assert_eq!(noc.levels, 6);
        // 1024 + 256 + 64 + 16 + 4 + 1 = 1365 (paper §IV-B).
        assert_eq!(noc.n_routers(), 1365);
    }

    #[test]
    fn regression_mode_all_accumulate() {
        // Fig. 7(a): single class, single batch → every router with used
        // cores below it accumulates; one flit reaches the CP.
        let cores: Vec<CoreImage> = (0..16).map(|_| core(0)).collect();
        let noc = NocConfig::build(&cores, 1, 16);
        assert!(noc.routers.iter().all(|r| r.accumulate));
        let inputs: Vec<(usize, f32)> = (0..16).map(|s| (s, 1.0)).collect();
        let out = noc.reduce(&inputs);
        assert_eq!(out, vec![(0, 0, 16.0)]);
    }

    #[test]
    fn multiclass_mode_separates_classes() {
        // Fig. 7(b): two classes alternating → the flit streams reaching
        // the CP keep per-class sums separate.
        let cores: Vec<CoreImage> = (0..8).map(|i| core((i % 2) as u16)).collect();
        let noc = NocConfig::build(&cores, 1, 8);
        let inputs: Vec<(usize, f32)> = (0..8).map(|s| (s, (s + 1) as f32)).collect();
        let mut out = noc.reduce(&inputs);
        out.sort_by_key(|&(c, r, _)| (c, r));
        let class0: f32 = out.iter().filter(|&&(c, _, _)| c == 0).map(|&(_, _, v)| v).sum();
        let class1: f32 = out.iter().filter(|&&(c, _, _)| c == 1).map(|&(_, _, v)| v).sum();
        assert_eq!(class0, 1.0 + 3.0 + 5.0 + 7.0);
        assert_eq!(class1, 2.0 + 4.0 + 6.0 + 8.0);
    }

    #[test]
    fn batching_mode_separates_replicas() {
        // Fig. 7(c): same class, 2 replicas of 4 cores → low-level routers
        // accumulate within a replica, upper ones pass through.
        let cores: Vec<CoreImage> = (0..4).map(|_| core(0)).collect();
        let noc = NocConfig::build(&cores, 2, 8);
        let inputs: Vec<(usize, f32)> = (0..8).map(|s| (s, 1.0)).collect();
        let mut out = noc.reduce(&inputs);
        out.sort_by_key(|&(c, r, _)| (c, r));
        assert_eq!(out, vec![(0, 0, 4.0), (0, 1, 4.0)]);
        // The leaf routers (level 1) covering each replica accumulate;
        // the root must not.
        let root = noc.routers.last().unwrap();
        assert!(!root.accumulate);
    }

    #[test]
    fn class_grouped_layout_accumulates_below_class_boundary() {
        // 4 cores class 0 then 4 cores class 1 (our compiler's layout):
        // level-1 routers are uniform → accumulate; root is mixed.
        let cores: Vec<CoreImage> =
            (0..8).map(|i| core(if i < 4 { 0 } else { 1 })).collect();
        let noc = NocConfig::build(&cores, 1, 8);
        let lvl1: Vec<bool> =
            noc.routers.iter().filter(|r| r.level == 1).map(|r| r.accumulate).collect();
        // 8 cores round up to 16 slots → 4 leaf routers; the two with used
        // cores below them are class-uniform (accumulate), the two over
        // empty slots are inert (bit = 0).
        assert_eq!(lvl1, vec![true, true, false, false]);
        assert!(!noc.routers.last().unwrap().accumulate);
        let out = noc.reduce(&(0..8).map(|s| (s, 1.0)).collect::<Vec<_>>());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn unused_slots_are_ignored() {
        let cores: Vec<CoreImage> = (0..3).map(|_| core(0)).collect();
        let noc = NocConfig::build(&cores, 1, 16);
        let out = noc.reduce(&[(0, 1.0), (1, 2.0), (2, 3.0)]);
        assert_eq!(out, vec![(0, 0, 6.0)]);
    }

    #[test]
    fn router_at_indexing() {
        let cores: Vec<CoreImage> = (0..4).map(|_| core(0)).collect();
        let noc = NocConfig::build(&cores, 1, 64);
        // 64 slots: level 1 → 16 routers (idx 0..16), level 2 → 4, level 3 → 1.
        assert_eq!(noc.levels, 3);
        assert_eq!(noc.router_at(1, 0), 0);
        assert_eq!(noc.router_at(1, 63), 15);
        assert_eq!(noc.router_at(2, 0), 16);
        assert_eq!(noc.router_at(3, 0), 20);
        assert_eq!(noc.n_routers(), 21);
    }
}
