//! Shard partitioner: one compiled ensemble → N self-contained programs.
//!
//! The paper's deployment (§III-D) is a host CPU offloading inference to
//! X-TIME PCIe cards. A single card caps capacity at 4096 cores and
//! throughput at one device's rate; spreading the trees of a large
//! ensemble across N cards is the scale-out lever (cf. RETENTION's
//! ensemble partitioning and MonoSparse-CAM's placement results). Because
//! tree ensembles reduce by *summation*, trees can be split arbitrarily
//! across devices: each shard computes a partial per-class sum and the
//! host aggregates `Σ_shards partials + base_score`.
//!
//! Each shard is a complete [`CamProgram`] — it repacks its trees into
//! class-uniform cores and rebuilds its own NoC configuration — so every
//! existing consumer (functional engine, cycle simulator, XLA runtime)
//! runs a shard unmodified. The full base score is carried by shard 0 and
//! zeroed elsewhere, so summing *standalone* shard logits is also correct.
//!
//! See `docs/adr/001-shard-placement.md` for why balanced-leaf-rows is the
//! default strategy.

use super::program::{pack_class_cores, CamProgram, CoreImage};
use super::noc::NocConfig;
use super::paths::CamRow;
use crate::cam::CORE_ROWS;
use crate::data::Task;
use crate::util::Json;
use std::collections::HashMap;

/// How trees are distributed across shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Round-robin by tree id: shard tree counts differ by at most one.
    /// Ignores tree size, so leaf-heavy trees can skew per-shard work.
    BalancedTrees,
    /// Longest-processing-time greedy on CAM row (≈ leaf) counts: each
    /// tree goes to the currently lightest shard. Rows drive both CAM
    /// search energy and functional-model cost, so this balances *work*.
    BalancedRows,
}

impl ShardStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            ShardStrategy::BalancedTrees => "balanced-trees",
            ShardStrategy::BalancedRows => "balanced-rows",
        }
    }

    /// Inverse of [`ShardStrategy::name`] (used by the plan decoder).
    pub fn from_name(name: &str) -> Result<ShardStrategy, String> {
        match name {
            "balanced-trees" => Ok(ShardStrategy::BalancedTrees),
            "balanced-rows" => Ok(ShardStrategy::BalancedRows),
            s => Err(format!("unknown shard strategy `{s}`")),
        }
    }
}

/// Partitioning options.
#[derive(Clone, Copy, Debug)]
pub struct PartitionOptions {
    pub strategy: ShardStrategy,
    /// Core word capacity used when repacking shard cores.
    pub core_rows: usize,
    /// Per-card core budget each shard must fit.
    pub chip_cores: usize,
}

impl Default for PartitionOptions {
    fn default() -> Self {
        PartitionOptions {
            strategy: ShardStrategy::BalancedRows,
            core_rows: CORE_ROWS,
            chip_cores: super::program::CHIP_CORES,
        }
    }
}

/// Partitioning error.
#[derive(Debug, PartialEq)]
pub enum PartitionError {
    /// `n_shards` was zero.
    NoShards,
    /// More shards requested than trees available to spread.
    TooManyShards { requested: usize, trees: usize },
    /// A single tree exceeds the repack core capacity.
    TreeTooLarge { tree: u32, leaves: usize, capacity: usize },
    /// A shard needs more cores than one card provides.
    ShardOverflow { shard: usize, needed: usize, available: usize },
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::NoShards => write!(f, "cannot partition into 0 shards"),
            PartitionError::TooManyShards { requested, trees } => {
                write!(f, "{requested} shards requested but only {trees} trees to spread")
            }
            PartitionError::TreeTooLarge { tree, leaves, capacity } => {
                write!(f, "tree {tree} has {leaves} leaves > shard core capacity {capacity}")
            }
            PartitionError::ShardOverflow { shard, needed, available } => {
                write!(f, "shard {shard} needs {needed} cores > {available} per card")
            }
        }
    }
}

impl std::error::Error for PartitionError {}

/// The result of partitioning: per-shard programs plus the aggregation
/// metadata the serving engine needs.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// One self-contained program per shard.
    pub shards: Vec<CamProgram>,
    /// Tree ids assigned to each shard (sorted ascending).
    pub assignment: Vec<Vec<u32>>,
    pub strategy: ShardStrategy,
    /// The source ensemble's additive prior, applied **once** when the
    /// host aggregates partial sums (shard 0 also carries it for
    /// standalone use; shards 1.. carry zeros).
    pub base_score: Vec<f32>,
    pub task: crate::data::Task,
    pub n_features: usize,
}

impl ShardPlan {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// CAM rows per shard — the balance the strategies optimize.
    pub fn rows_per_shard(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.total_rows()).collect()
    }

    /// Trees per shard.
    pub fn trees_per_shard(&self) -> Vec<usize> {
        self.assignment.iter().map(|a| a.len()).collect()
    }

    /// Max/min row-count ratio across shards (1.0 = perfectly balanced).
    pub fn row_imbalance(&self) -> f64 {
        let rows = self.rows_per_shard();
        let max = *rows.iter().max().unwrap_or(&0) as f64;
        let min = *rows.iter().min().unwrap_or(&0) as f64;
        if min == 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }

    // ---- serialization ---------------------------------------------------

    /// Canonical encoding (see [`CamProgram::to_json`]): shard programs
    /// nest their own canonical encodings, floats are bit-exact, and
    /// encode→decode→encode is byte-identical — the digest-stability
    /// contract of the artifact store (`crate::artifact`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("strategy", Json::Str(self.strategy.name().to_string()))
            .set("task", Json::Str(self.task.name()))
            .set("n_classes", Json::Num(self.task.n_classes() as f64))
            .set("n_features", Json::Num(self.n_features as f64))
            .set("base_score", Json::from_canon_f32_slice(&self.base_score))
            .set(
                "assignment",
                Json::Arr(
                    self.assignment
                        .iter()
                        .map(|a| {
                            Json::from_usize_slice(
                                &a.iter().map(|&t| t as usize).collect::<Vec<_>>(),
                            )
                        })
                        .collect(),
                ),
            )
            .set("shards", Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()));
        o
    }

    /// Bit-exact inverse of [`ShardPlan::to_json`].
    pub fn from_json(j: &Json) -> Result<ShardPlan, String> {
        let strategy = ShardStrategy::from_name(j.req_str("strategy")?)?;
        let task = Task::from_name(j.req_str("task")?, j.req_usize("n_classes")?)?;
        let shards = j
            .req_arr("shards")?
            .iter()
            .map(CamProgram::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let assignment = j
            .req_arr("assignment")?
            .iter()
            .map(|a| a.usize_vec().map(|v| v.into_iter().map(|t| t as u32).collect()))
            .collect::<Result<Vec<Vec<u32>>, _>>()?;
        if shards.is_empty() {
            return Err("shard plan has no shards".into());
        }
        if shards.len() != assignment.len() {
            return Err(format!(
                "shard plan has {} shards but {} assignment rows",
                shards.len(),
                assignment.len()
            ));
        }
        Ok(ShardPlan {
            shards,
            assignment,
            strategy,
            base_score: j.req("base_score")?.canon_f32_vec()?,
            task,
            n_features: j.req_usize("n_features")?,
        })
    }
}

/// One tree's rows, recovered from a compiled program.
struct TreeRows {
    id: u32,
    class: u16,
    rows: Vec<CamRow>,
}

/// Recover per-tree row groups from the compiled core images. Row order
/// within each tree is preserved (it matches extraction order), so shard
/// programs reproduce the original rows exactly.
fn trees_of(program: &CamProgram) -> Vec<TreeRows> {
    let mut index: HashMap<u32, usize> = HashMap::new();
    let mut trees: Vec<TreeRows> = Vec::new();
    for core in &program.cores {
        for row in &core.rows {
            let at = *index.entry(row.tree).or_insert_with(|| {
                trees.push(TreeRows { id: row.tree, class: core.class, rows: Vec::new() });
                trees.len() - 1
            });
            trees[at].rows.push(row.clone());
        }
    }
    trees.sort_by_key(|t| t.id);
    trees
}

/// Split `program`'s trees into `n_shards` self-contained programs.
///
/// Correctness invariant (tested in `rust/tests/sharding.rs`): for every
/// input, summing the shards' base-free partial sums in shard order and
/// adding `base_score` reproduces the unsharded functional engine's
/// logits exactly.
pub fn partition(
    program: &CamProgram,
    n_shards: usize,
    options: &PartitionOptions,
) -> Result<ShardPlan, PartitionError> {
    if n_shards == 0 {
        return Err(PartitionError::NoShards);
    }
    let trees = trees_of(program);
    if n_shards > trees.len() {
        return Err(PartitionError::TooManyShards { requested: n_shards, trees: trees.len() });
    }
    for t in &trees {
        if t.rows.len() > options.core_rows {
            return Err(PartitionError::TreeTooLarge {
                tree: t.id,
                leaves: t.rows.len(),
                capacity: options.core_rows,
            });
        }
    }

    // Assign trees to shards.
    let mut shard_of = vec![0usize; trees.len()];
    match options.strategy {
        ShardStrategy::BalancedTrees => {
            for (i, s) in shard_of.iter_mut().enumerate() {
                *s = i % n_shards;
            }
        }
        ShardStrategy::BalancedRows => {
            // LPT: biggest trees first, each to the lightest shard.
            let mut order: Vec<usize> = (0..trees.len()).collect();
            order.sort_by_key(|&i| (std::cmp::Reverse(trees[i].rows.len()), trees[i].id));
            let mut load = vec![0usize; n_shards];
            for i in order {
                let lightest = (0..n_shards).min_by_key(|&s| (load[s], s)).unwrap();
                shard_of[i] = lightest;
                load[lightest] += trees[i].rows.len();
            }
        }
    }

    // Build each shard as a complete program.
    let k = program.task.n_outputs().max(1);
    let mut shards = Vec::with_capacity(n_shards);
    let mut assignment = vec![Vec::new(); n_shards];
    for s in 0..n_shards {
        let mut class_trees: Vec<Vec<(u32, Vec<CamRow>)>> = vec![Vec::new(); k];
        for (i, t) in trees.iter().enumerate() {
            if shard_of[i] == s {
                class_trees[t.class as usize].push((t.id, t.rows.clone()));
                assignment[s].push(t.id);
            }
        }
        let mut cores: Vec<CoreImage> = Vec::new();
        for (class, ct) in class_trees.iter().enumerate() {
            cores.extend(pack_class_cores(class as u16, ct, options.core_rows));
        }
        if cores.len() > options.chip_cores {
            return Err(PartitionError::ShardOverflow {
                shard: s,
                needed: cores.len(),
                available: options.chip_cores,
            });
        }
        // Preserve the source's within-card replication (Fig. 7c input
        // batching) as far as the shard's spare cores allow — sharding is
        // the capacity lever, replication stays the batching lever.
        let max_replicas = (options.chip_cores / cores.len()).max(1);
        let n_replicas = program.n_replicas.clamp(1, max_replicas);
        let noc = NocConfig::build(&cores, n_replicas, options.chip_cores);
        let base_score = if s == 0 {
            program.base_score.clone()
        } else {
            vec![0.0; program.base_score.len()]
        };
        let n_trees = assignment[s].len();
        let mut shard = CamProgram {
            name: format!("{}::shard{}of{}", program.name, s, n_shards),
            task: program.task,
            n_features: program.n_features,
            n_bins: program.n_bins,
            n_bits: program.n_bits,
            base_score,
            cores,
            n_replicas,
            noc,
            quantizer: program.quantizer.clone(),
            n_trees,
            layouts: None,
        };
        // A compressed source yields compressed shards: the shard's row
        // distribution differs from the source's, so its physical layout
        // is recomputed from scratch rather than sliced out of the
        // source's (contract 11 — the layout is only an annotation).
        if program.layouts.is_some() {
            super::compress::compress_program(&mut shard);
        }
        shards.push(shard);
    }

    Ok(ShardPlan {
        shards,
        assignment,
        strategy: options.strategy,
        base_score: program.base_score.clone(),
        task: program.task,
        n_features: program.n_features,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn program(rounds: usize) -> CamProgram {
        let d = by_name("churn").unwrap().generate_n(900);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: rounds, max_leaves: 16, ..Default::default() },
            None,
        );
        compile(&m, &CompileOptions::default()).unwrap()
    }

    #[test]
    fn covers_all_trees_disjointly() {
        let p = program(12);
        for strategy in [ShardStrategy::BalancedTrees, ShardStrategy::BalancedRows] {
            let plan = partition(
                &p,
                3,
                &PartitionOptions { strategy, ..Default::default() },
            )
            .unwrap();
            let mut all: Vec<u32> = plan.assignment.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..12u32).collect::<Vec<_>>(), "{strategy:?}");
            assert_eq!(
                plan.shards.iter().map(|s| s.total_rows()).sum::<usize>(),
                p.total_rows()
            );
        }
    }

    #[test]
    fn balanced_trees_within_one() {
        let p = program(13);
        let plan = partition(
            &p,
            4,
            &PartitionOptions { strategy: ShardStrategy::BalancedTrees, ..Default::default() },
        )
        .unwrap();
        let counts = plan.trees_per_shard();
        let (mi, ma) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(ma - mi <= 1, "{counts:?}");
    }

    #[test]
    fn balanced_rows_meets_greedy_bound() {
        let p = program(16);
        let plan = partition(
            &p,
            4,
            &PartitionOptions { strategy: ShardStrategy::BalancedRows, ..Default::default() },
        )
        .unwrap();
        // Greedy-lightest bound: worst shard ≤ mean load + biggest tree.
        let rows = plan.rows_per_shard();
        let total: usize = rows.iter().sum();
        let biggest_tree = {
            let mut sizes: HashMap<u32, usize> = HashMap::new();
            for c in &p.cores {
                for r in &c.rows {
                    *sizes.entry(r.tree).or_insert(0) += 1;
                }
            }
            *sizes.values().max().unwrap()
        };
        assert!(
            *rows.iter().max().unwrap() <= total.div_ceil(4) + biggest_tree,
            "{rows:?} vs bound {} + {biggest_tree}",
            total.div_ceil(4)
        );
        assert!(plan.row_imbalance() >= 1.0);
    }

    #[test]
    fn base_score_on_shard_zero_only() {
        let p = program(8);
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        assert_eq!(plan.shards[0].base_score, p.base_score);
        assert!(plan.shards[1].base_score.iter().all(|&b| b == 0.0));
        assert_eq!(plan.base_score, p.base_score);
    }

    #[test]
    fn rejects_degenerate_requests() {
        let p = program(4);
        assert!(matches!(
            partition(&p, 0, &PartitionOptions::default()),
            Err(PartitionError::NoShards)
        ));
        assert!(matches!(
            partition(&p, 5, &PartitionOptions::default()),
            Err(PartitionError::TooManyShards { requested: 5, trees: 4 })
        ));
    }

    #[test]
    fn shard_plan_json_codec_is_canonical() {
        let p = program(10);
        for strategy in [ShardStrategy::BalancedTrees, ShardStrategy::BalancedRows] {
            let plan =
                partition(&p, 2, &PartitionOptions { strategy, ..Default::default() }).unwrap();
            let text = plan.to_json().to_string();
            let back = ShardPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
            // Canonical: decoded plan re-encodes to identical bytes.
            assert_eq!(back.to_json().to_string(), text, "{strategy:?}");
            assert_eq!(back.strategy, plan.strategy);
            assert_eq!(back.assignment, plan.assignment);
            assert_eq!(back.task, plan.task);
            assert_eq!(back.n_features, plan.n_features);
            for (a, b) in plan.shards.iter().zip(&back.shards) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.noc.routers, b.noc.routers);
                for (ca, cb) in a.cores.iter().zip(&b.cores) {
                    assert_eq!(ca.rows, cb.rows);
                    assert_eq!(ca.trees, cb.trees);
                }
            }
            for (x, y) in plan.base_score.iter().zip(&back.base_score) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert!(ShardStrategy::from_name("nope").is_err());
    }

    #[test]
    fn shard_programs_are_self_contained() {
        let p = program(10);
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        for (s, shard) in plan.shards.iter().enumerate() {
            assert_eq!(shard.n_features, p.n_features);
            assert_eq!(shard.n_trees, plan.assignment[s].len());
            assert!(shard.cores.iter().all(|c| c.rows.iter().all(|r| r.class == c.class)));
            // The engine can run a shard directly.
            let e = crate::compiler::CamEngine::new(shard);
            let bins = vec![0u16; shard.n_features];
            assert_eq!(e.infer_bins(&bins).len(), p.task.n_outputs());
        }
    }
}
