"""AOT pipeline: lower the L2 graph to HLO text artifacts for the Rust
runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md and
aot_recipe.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``<bucket>.hlo.txt`` per entry in ``model.BUCKETS`` plus a
``manifest.json`` describing shapes, padding conventions and the kernel
mode, which ``rust/src/runtime`` consumes to pick buckets at serving time.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import (
    BUCKETS,
    DC_HI,
    DC_LO,
    PAD_HI,
    PAD_LO,
    bucket_args,
    bucket_args_fast,
    bucket_fn,
    bucket_fn_fast,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, mode: str = "fast_u8") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    fast = mode == "fast_u8"
    fn = bucket_fn_fast() if fast else bucket_fn(mode)
    manifest = {
        "format": "hlo-text",
        "kernel_mode": mode,
        "layout": "transposed_u8" if fast else "batch_major_i32",
        "pad": {"row_lo": PAD_LO, "row_hi": PAD_HI, "feat_lo": DC_LO, "feat_hi": DC_HI},
        "inputs": (
            ["qt[u8,F,B]", "lo[u8,N,F]", "hi_inc[u8,N,F]", "leaf[f32,N,K]"]
            if fast
            else ["q[i32,B,F]", "lo[i32,N,F]", "hi[i32,N,F]", "leaf[f32,N,K]"]
        ),
        "output": "logits[f32,K,B] (1-tuple)" if fast else "logits[f32,B,K] (1-tuple)",
        "buckets": [],
    }
    for bucket in BUCKETS:
        args = bucket_args_fast(bucket) if fast else bucket_args(bucket)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{bucket.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append(
            {
                "file": fname,
                "batch": bucket.batch,
                "features": bucket.features,
                "rows": bucket.rows,
                "classes": bucket.classes,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "hlo_bytes": len(text),
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(manifest['buckets'])} buckets)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--mode",
        default="fast_u8",
        choices=["fast_u8", "direct", "macro_cell"],
        help="CAM match formulation baked into the artifacts (fast_u8 = "
        "perf-optimized u8/transposed layout; direct/macro_cell = "
        "batch-major i32 hardware-mode kernels)",
    )
    args = ap.parse_args()
    build(args.out, args.mode)


if __name__ == "__main__":
    main()
