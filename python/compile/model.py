"""L2 JAX model: the X-TIME ensemble-inference compute graph.

The chip-level computation for one batch is:

  bins -> per-row CAM match -> leaf gather -> class-wise reduce -> logits

which the L1 kernel fuses into a single match+matmul. This module wraps
it into the shape-bucketed functions that get AOT-lowered (``aot.py``) and
defines the padding conventions shared with the Rust runtime
(``rust/src/runtime/``):

* feature padding: extra columns get ``lo=0, hi=256`` (don't care) and the
  query pads with zeros;
* row padding: ``lo=256, hi=0`` windows never match; their leaf row is 0;
* class padding: unused class columns carry zero leaves.

The Rust side owns quantization (the DAC) and the base-score/threshold/
argmax decision (the CP); this graph is exactly the in-fabric part.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.cam_match import cam_infer, cam_infer_fast

# Never-matching padding row bounds (lo > any query, hi = 0).
PAD_LO = 256
PAD_HI = 0
# Don't-care bounds for padded feature columns.
DC_LO = 0
DC_HI = 256


@dataclass(frozen=True)
class Bucket:
    """A monomorphic artifact shape: batch × features × rows × classes."""

    batch: int
    features: int
    rows: int
    classes: int

    @property
    def name(self) -> str:
        return f"cam_b{self.batch}_f{self.features}_n{self.rows}_k{self.classes}"


#: The artifact set built by ``make artifacts``. Chosen to cover the
#: Table II model range after padding: F ≤ 130 (gas), medium/large row
#: counts, single-sample (latency) and batched (throughput) entry points.
BUCKETS = [
    Bucket(batch=1, features=32, rows=2048, classes=8),
    Bucket(batch=64, features=32, rows=2048, classes=8),
    Bucket(batch=1, features=130, rows=2048, classes=8),
    Bucket(batch=64, features=130, rows=2048, classes=8),
    Bucket(batch=64, features=32, rows=8192, classes=8),
    Bucket(batch=64, features=130, rows=8192, classes=8),
    Bucket(batch=64, features=32, rows=16384, classes=8),
    Bucket(batch=64, features=130, rows=16384, classes=8),
    # Quickstart-size bucket (tiny, fast to compile and run everywhere).
    Bucket(batch=8, features=16, rows=256, classes=8),
]


def xtime_infer(q, lo, hi, leaf, *, mode: str = "direct"):
    """The L2 graph: bins + programmed bounds + leaf table → logits.

    All shape/padding handling happens at compile (bucket) time; this
    function is pure compute so XLA sees one fused pipeline.
    """
    return cam_infer(q, lo, hi, leaf, mode=mode)


def bucket_fn(mode: str = "direct"):
    """The jittable entry point lowered per bucket."""

    def fn(q, lo, hi, leaf):
        return (xtime_infer(q, lo, hi, leaf, mode=mode),)

    return fn


def bucket_args(bucket: Bucket):
    """abstract input signature for lowering a bucket."""
    return (
        jax.ShapeDtypeStruct((bucket.batch, bucket.features), jnp.int32),
        jax.ShapeDtypeStruct((bucket.rows, bucket.features), jnp.int32),
        jax.ShapeDtypeStruct((bucket.rows, bucket.features), jnp.int32),
        jax.ShapeDtypeStruct((bucket.rows, bucket.classes), jnp.float32),
    )


def bucket_fn_fast():
    """Optimized artifact entry point (perf pass, EXPERIMENTS.md §Perf):
    u8-packed bounds, transposed query/logit layout. Inputs:
    ``qt[u8, F, B], lo[u8, N, F], hi_inc[u8, N, F], leaf[f32, N, K]`` →
    ``logits[f32, K, B]`` where ``hi_inc`` is the INCLUSIVE upper bound."""

    def fn(qt, lo, hi_inc, leaf):
        return (cam_infer_fast(qt, lo, hi_inc, leaf),)

    return fn


def bucket_args_fast(bucket: Bucket):
    return (
        jax.ShapeDtypeStruct((bucket.features, bucket.batch), jnp.uint8),
        jax.ShapeDtypeStruct((bucket.rows, bucket.features), jnp.uint8),
        jax.ShapeDtypeStruct((bucket.rows, bucket.features), jnp.uint8),
        jax.ShapeDtypeStruct((bucket.rows, bucket.classes), jnp.float32),
    )


def pad_program(lo, hi, leaf, bucket: Bucket):
    """Pad concrete program tensors into a bucket's shapes (test helper;
    the Rust runtime reimplements this in ``runtime/buckets.rs``)."""
    n, f = lo.shape
    k = leaf.shape[1]
    assert n <= bucket.rows and f <= bucket.features and k <= bucket.classes
    plo = jnp.full((bucket.rows, bucket.features), DC_LO, jnp.int32)
    phi = jnp.full((bucket.rows, bucket.features), DC_HI, jnp.int32)
    # Padding rows must never match.
    plo = plo.at[n:, :].set(PAD_LO)
    phi = phi.at[n:, :].set(PAD_HI)
    plo = plo.at[:n, :f].set(lo)
    phi = phi.at[:n, :f].set(hi)
    pleaf = jnp.zeros((bucket.rows, bucket.classes), jnp.float32)
    pleaf = pleaf.at[:n, :k].set(leaf)
    return plo, phi, pleaf


def pad_query(q, bucket: Bucket):
    """Pad a query batch ``[b, f]`` into bucket shape (zeros everywhere)."""
    b, f = q.shape
    assert b <= bucket.batch and f <= bucket.features
    pq = jnp.zeros((bucket.batch, bucket.features), jnp.int32)
    return pq.at[:b, :f].set(q)
