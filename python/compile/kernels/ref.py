"""Pure-jnp oracle for the CAM match/accumulate kernels.

This is the L1 correctness reference: the Pallas kernels in
``cam_match.py`` must agree with these functions exactly (the match is an
integer/boolean computation, and the leaf accumulation is a sum of exact
0/1-weighted f32 values, so equality is bit-level up to f32 summation
order; tests use exact comparison on the match matrix and tight allclose
on the logits).
"""

from __future__ import annotations

import jax.numpy as jnp

SUB_LEVELS = 16  # 4-bit memristor levels (M = 4)


def cam_match_ref(q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Ideal interval match.

    Args:
      q:  ``[B, F]`` integer query bins.
      lo: ``[N, F]`` inclusive lower bounds.
      hi: ``[N, F]`` exclusive upper bounds.

    Returns:
      ``[B, N]`` boolean: row n matches query b iff
      ``all_f(lo[n,f] <= q[b,f] < hi[n,f])``.
    """
    qb = q[:, None, :]  # [B, 1, F]
    ge = qb >= lo[None, :, :]
    lt = qb < hi[None, :, :]
    return jnp.all(ge & lt, axis=-1)


def cam_match_macro_ref(q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Two-cycle 8-bit-on-4-bit macro-cell match — Eq. (3) of the paper.

    Decomposes queries and bounds into 4-bit MSB/LSB halves and evaluates

      [(q_MSB >= T_LMSB + 1) | (q_LSB >= T_LLSB)] & (q_MSB >= T_LMSB)
      & [(q_MSB < T_HMSB) | (q_LSB < T_HLSB)] & (q_MSB < T_HMSB + 1)

    per cell, ANDing along features. Provably equal to ``cam_match_ref``
    for 8-bit inputs; kept separate so the hardware formulation is
    independently testable (Rust mirrors it in ``cam/cell.rs``).
    """
    qm, ql = q // SUB_LEVELS, q % SUB_LEVELS
    tlm, tll = lo // SUB_LEVELS, lo % SUB_LEVELS
    thm, thl = hi // SUB_LEVELS, hi % SUB_LEVELS

    qm_b, ql_b = qm[:, None, :], ql[:, None, :]
    c1_lower = (qm_b >= tlm[None] + 1) | (ql_b >= tll[None])
    c2_lower = qm_b >= tlm[None]
    c1_upper = (qm_b < thm[None]) | (ql_b < thl[None])
    c2_upper = qm_b < thm[None] + 1
    cell = c1_lower & c2_lower & c1_upper & c2_upper
    return jnp.all(cell, axis=-1)


def cam_infer_ref(
    q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, leaf: jnp.ndarray
) -> jnp.ndarray:
    """Full ensemble inference oracle.

    The CAM match (one-hot per tree) followed by the leaf gather and the
    class-wise in-network reduction is exactly a matmul of the 0/1 match
    matrix with the per-class leaf table (DESIGN.md §Hardware-Adaptation).

    Args:
      q:    ``[B, F]`` query bins.
      lo:   ``[N, F]`` lower bounds (N = total CAM rows over all cores).
      hi:   ``[N, F]`` upper bounds.
      leaf: ``[N, K]`` leaf logits scattered into their class column.

    Returns:
      ``[B, K]`` accumulated logits (before base-score offset, which the
      Rust co-processor adds).
    """
    match = cam_match_ref(q, lo, hi)
    return jnp.dot(match.astype(jnp.float32), leaf)
