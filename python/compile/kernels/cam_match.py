"""L1 Pallas kernel: fused analog-CAM match + leaf accumulation.

The X-TIME hot spot — "search every stored root-to-leaf window against the
query, then gather + class-reduce the matched leaves" — maps onto the TPU
as one fused kernel (DESIGN.md §Hardware-Adaptation):

* the massively parallel match-line comparison becomes a **vectorized
  interval compare** over a `[rows × features]` tile resident in VMEM
  (the VMEM tile plays the role of the aCAM array; the HBM→VMEM BlockSpec
  schedule plays the role of the stacked/queued array organization);
* the MMR + SRAM gather + in-core ACC + in-network reduction collapse
  into a **match-matrix × leaf-table matmul** targeting the MXU — the
  match matrix is 0/1-valued so low-precision accumulation is exact.

Two match modes are provided:

* ``direct``     — the ideal 8-bit comparison ``lo <= q < hi``;
* ``macro_cell`` — the paper's two-cycle MSB/LSB evaluation (Eq. 3),
  bit-identical to ``direct`` for 8-bit inputs (proven in tests), kept as
  a faithful functional model of the increased-precision macro-cell.

VMEM budget (documented for the real-TPU estimate in DESIGN.md §Perf):
with the default tiles ``TB=64, TN=256`` at F=130, K=8 the working set is
  q 64×130×4B = 33 KB, lo/hi 2×256×130×4B = 266 KB, leaf 256×8×4B = 8 KB,
  match 64×256×4B = 64 KB, out 64×8×4B = 2 KB  →  ≈ 0.4 MB ≪ 16 MB VMEM,
leaving room for double buffering of the N-dimension stream.

Kernels are compiled with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUB_LEVELS = 16


def _match_tile(q, lo, hi, mode: str):
    """Match a query tile ``[TB, F]`` against a bounds tile ``[TN, F]``.

    Returns float32 ``[TB, TN]`` (0.0 / 1.0) ready for the MXU matmul.
    """
    qb = q[:, None, :]  # [TB, 1, F]
    if mode == "direct":
        cell = (qb >= lo[None]) & (qb < hi[None])
    elif mode == "macro_cell":
        qm, ql = qb // SUB_LEVELS, qb % SUB_LEVELS
        tlm, tll = lo[None] // SUB_LEVELS, lo[None] % SUB_LEVELS
        thm, thl = hi[None] // SUB_LEVELS, hi[None] % SUB_LEVELS
        # Cycle 1: the OR brackets of Eq. (3); cycle 2: the MSB-only terms.
        cycle1 = ((qm >= tlm + 1) | (ql >= tll)) & ((qm < thm) | (ql < thl))
        cycle2 = (qm >= tlm) & (qm < thm + 1)
        cell = cycle1 & cycle2
    else:
        raise ValueError(f"unknown match mode {mode!r}")
    return jnp.all(cell, axis=-1).astype(jnp.float32)


def _kernel(q_ref, lo_ref, hi_ref, leaf_ref, out_ref, *, mode: str):
    """Grid = (B/TB, N/TN); the N dimension accumulates into out_ref."""
    n_idx = pl.program_id(1)
    match = _match_tile(q_ref[...], lo_ref[...], hi_ref[...], mode)
    partial = jnp.dot(match, leaf_ref[...], preferred_element_type=jnp.float32)

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(n_idx > 0)
    def _acc():
        out_ref[...] += partial


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is ≤ preferred (shape-safe tiling)."""
    t = min(preferred, dim)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("mode", "tile_b", "tile_n"))
def cam_infer(
    q: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    leaf: jnp.ndarray,
    *,
    mode: str = "direct",
    tile_b: int = 64,
    tile_n: int = 256,
) -> jnp.ndarray:
    """Fused CAM inference: ``[B,K] = onehot_match(q; lo, hi) @ leaf``.

    Args:
      q:    ``[B, F]`` int32 query bins (0..255).
      lo:   ``[N, F]`` int32 inclusive lower bounds.
      hi:   ``[N, F]`` int32 exclusive upper bounds (≤ 256; padding rows
            use ``lo=256, hi=0`` so they never match).
      leaf: ``[N, K]`` float32 leaf logits in their class column.
      mode: ``direct`` or ``macro_cell`` (Eq. 3 two-cycle evaluation).

    Returns:
      ``[B, K]`` float32 logits (base score added downstream by the CP).
    """
    b, f = q.shape
    n, f2 = lo.shape
    assert f == f2 and hi.shape == lo.shape, "bounds shape mismatch"
    assert leaf.shape[0] == n, "leaf table row mismatch"
    k = leaf.shape[1]

    tb = _pick_tile(b, tile_b)
    tn = _pick_tile(n, tile_n)
    grid = (b // tb, n // tn)

    return pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, f), lambda ib, in_: (ib, 0)),
            pl.BlockSpec((tn, f), lambda ib, in_: (in_, 0)),
            pl.BlockSpec((tn, f), lambda ib, in_: (in_, 0)),
            pl.BlockSpec((tn, k), lambda ib, in_: (in_, 0)),
        ],
        out_specs=pl.BlockSpec((tb, k), lambda ib, in_: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, lo, hi, leaf)


def _kernel_fast(qt_ref, lo_ref, hi_ref, leaf_ref, out_ref):
    """Transposed u8 kernel — the production artifact path.

    Perf-pass result (EXPERIMENTS.md §Perf): int32 batch-major tiles run
    memory-bound on re-streaming the bounds table per query row. Packing
    bounds/queries to u8 (4× less traffic; `hi` stored *inclusive* so 256
    fits in a byte) and transposing so the **batch** dimension is
    innermost (each bounds cache line is reused across all queries in one
    vector op) gives 107 ms → 25.7 ms on the B=64, N=16384, F=130 bucket.
    On a real TPU the same layout maps naturally: batch along lanes,
    bounds rows along sublanes, leaf matmul on the MXU.
    """
    n_idx = pl.program_id(0)
    qt = qt_ref[...]  # [F, B] u8
    lo = lo_ref[...]  # [TN, F] u8
    hi = hi_ref[...]  # [TN, F] u8, inclusive upper bound
    cell = (qt[None] >= lo[:, :, None]) & (qt[None] <= hi[:, :, None])  # [TN,F,B]
    match = jnp.all(cell, axis=1).astype(jnp.float32)  # [TN, B]
    partial = jnp.dot(leaf_ref[...].T, match, preferred_element_type=jnp.float32)

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(n_idx > 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("tile_n",))
def cam_infer_fast(
    qt: jnp.ndarray,
    lo: jnp.ndarray,
    hi_inc: jnp.ndarray,
    leaf: jnp.ndarray,
    *,
    tile_n: int = 2048,
) -> jnp.ndarray:
    """Optimized fused inference (see `_kernel_fast`).

    Args:
      qt:     ``[F, B]`` uint8 transposed query bins.
      lo:     ``[N, F]`` uint8 inclusive lower bounds.
      hi_inc: ``[N, F]`` uint8 INCLUSIVE upper bounds (= ``hi - 1``;
              never-match padding rows use ``lo=255, hi_inc=0``).
      leaf:   ``[N, K]`` float32 leaf logits.

    Returns:
      ``[K, B]`` float32 logits (transposed, matching the kernel layout).
    """
    f, b = qt.shape
    n, f2 = lo.shape
    assert f == f2 and hi_inc.shape == lo.shape and leaf.shape[0] == n
    k = leaf.shape[1]
    tn = _pick_tile(n, tile_n)
    return pl.pallas_call(
        _kernel_fast,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((f, b), lambda i: (0, 0)),
            pl.BlockSpec((tn, f), lambda i: (i, 0)),
            pl.BlockSpec((tn, f), lambda i: (i, 0)),
            pl.BlockSpec((tn, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, b), jnp.float32),
        interpret=True,
    )(qt, lo, hi_inc, leaf)


def cam_match(
    q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, *, mode: str = "direct"
) -> jnp.ndarray:
    """Match matrix only (debug/visibility path): ``[B, N]`` float32 0/1.

    Implemented via the fused kernel with an identity-per-row leaf table
    would be O(N²); instead this thin Pallas kernel materializes the tile
    match directly.
    """
    b, f = q.shape
    n, _ = lo.shape
    tb = _pick_tile(b, 64)
    tn = _pick_tile(n, 256)

    def kernel(q_ref, lo_ref, hi_ref, out_ref):
        out_ref[...] = _match_tile(q_ref[...], lo_ref[...], hi_ref[...], mode)

    return pl.pallas_call(
        kernel,
        grid=(b // tb, n // tn),
        in_specs=[
            pl.BlockSpec((tb, f), lambda ib, in_: (ib, 0)),
            pl.BlockSpec((tn, f), lambda ib, in_: (in_, 0)),
            pl.BlockSpec((tn, f), lambda ib, in_: (in_, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda ib, in_: (ib, in_)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(q, lo, hi)
