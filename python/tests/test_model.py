"""L2 model + AOT pipeline tests: padding semantics, bucket lowering and
manifest integrity."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import build, to_hlo_text
from compile.kernels.ref import cam_infer_ref
from compile.model import (
    BUCKETS,
    Bucket,
    bucket_args,
    bucket_fn,
    pad_program,
    pad_query,
    xtime_infer,
)


def small_case(rng, b=4, n=20, f=7, k=3):
    q = rng.integers(0, 256, size=(b, f)).astype(np.int32)
    lo = rng.integers(0, 200, size=(n, f)).astype(np.int32)
    hi = np.minimum(lo + rng.integers(1, 60, size=(n, f)), 256).astype(np.int32)
    leaf = rng.standard_normal((n, k)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(leaf)


def test_padding_preserves_logits():
    """Padding rows/features/classes must not change the result — the
    contract the Rust runtime relies on when bucketing programs."""
    rng = np.random.default_rng(11)
    q, lo, hi, leaf = small_case(rng)
    bucket = Bucket(batch=8, features=16, rows=256, classes=8)
    plo, phi, pleaf = pad_program(lo, hi, leaf, bucket)
    pq = pad_query(q, bucket)
    padded = np.asarray(xtime_infer(pq, plo, phi, pleaf))
    want = np.asarray(cam_infer_ref(q, lo, hi, leaf))
    np.testing.assert_allclose(padded[:4, :3], want, rtol=1e-6, atol=1e-6)
    # Pad batch rows see only don't-care features on real rows... they may
    # match real windows at q=0; correctness only requires the *real*
    # batch rows to be exact, which is asserted above. Padded class
    # columns must be exactly zero.
    np.testing.assert_array_equal(padded[:, 3:], 0.0)


def test_bucket_lowering_produces_hlo_text():
    bucket = Bucket(batch=2, features=8, rows=64, classes=4)
    lowered = jax.jit(bucket_fn("direct")).lower(*bucket_args(bucket))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,4]" in text  # output logits shape


def test_build_writes_manifest_and_artifacts():
    with tempfile.TemporaryDirectory() as d:
        # Patch BUCKETS to a tiny set for test speed by building only the
        # quickstart bucket through the public API.
        import compile.aot as aot
        import compile.model as model

        orig = model.BUCKETS
        try:
            model.BUCKETS = [Bucket(batch=2, features=8, rows=64, classes=4)]
            # aot.build reads the symbol through its own import.
            aot.BUCKETS = model.BUCKETS
            manifest = aot.build(d)
        finally:
            model.BUCKETS = orig
            aot.BUCKETS = orig
        files = os.listdir(d)
        assert "manifest.json" in files
        assert any(f.endswith(".hlo.txt") for f in files)
        with open(os.path.join(d, "manifest.json")) as f:
            m = json.load(f)
        assert m == manifest
        assert m["format"] == "hlo-text"
        b = m["buckets"][0]
        assert (b["batch"], b["features"], b["rows"], b["classes"]) == (2, 8, 64, 4)
        text = open(os.path.join(d, b["file"])).read()
        assert len(text) == b["hlo_bytes"]


def test_default_buckets_cover_table2_models():
    """Every Table II model shape must fit some bucket after padding:
    F ≤ 130 always; the serving path needs at least one bucket with
    batch = 1 (latency) and one with batch ≥ 64 (throughput)."""
    assert any(b.features >= 130 for b in BUCKETS)
    assert any(b.batch == 1 for b in BUCKETS)
    assert any(b.batch >= 64 for b in BUCKETS)
    assert all(b.classes >= 7 for b in BUCKETS)  # covertype has 7 classes


def test_bucket_names_unique():
    names = [b.name for b in BUCKETS]
    assert len(set(names)) == len(names)
