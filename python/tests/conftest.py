"""Shared pytest config: hypothesis profile for the offline CI image
(interpret-mode Pallas calls are slow; disable deadlines, derandomize)."""

import hypothesis

hypothesis.settings.register_profile(
    "offline", deadline=None, max_examples=30, derandomize=True
)
hypothesis.settings.load_profile("offline")
