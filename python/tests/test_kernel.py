"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; the match matrix must be *exactly*
equal (it is a boolean computation) and logits allclose at f32 tolerance.
"""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile.kernels.cam_match import cam_infer, cam_match
from compile.kernels.ref import cam_infer_ref, cam_match_macro_ref, cam_match_ref


def random_case(rng, b, n, f, k, dont_care=0.2, never=0.05):
    """Random bounds with don't-care cells, never-match rows, real windows."""
    q = rng.integers(0, 256, size=(b, f), dtype=np.int32)
    lo = rng.integers(0, 200, size=(n, f)).astype(np.int32)
    width = rng.integers(1, 80, size=(n, f)).astype(np.int32)
    hi = np.minimum(lo + width, 256).astype(np.int32)
    dc = rng.random((n, f)) < dont_care
    lo[dc], hi[dc] = 0, 256
    nm = rng.random(n) < never
    lo[nm, :], hi[nm, :] = 256, 0
    leaf = rng.standard_normal((n, k)).astype(np.float32)
    leaf[nm, :] = 0.0
    return jnp.asarray(q), jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(leaf)


@given(
    b=st.integers(1, 9),
    n=st.integers(1, 70),
    f=st.integers(1, 20),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_kernel_matches_oracle(b, n, f, k, seed):
    rng = np.random.default_rng(seed)
    q, lo, hi, leaf = random_case(rng, b, n, f, k)
    got = cam_infer(q, lo, hi, leaf, mode="direct")
    want = cam_infer_ref(q, lo, hi, leaf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@given(
    b=st.integers(1, 6),
    n=st.integers(1, 40),
    f=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_match_kernel_exact(b, n, f, seed):
    rng = np.random.default_rng(seed)
    q, lo, hi, _ = random_case(rng, b, n, f, 1)
    got = np.asarray(cam_match(q, lo, hi, mode="direct"))
    want = np.asarray(cam_match_ref(q, lo, hi)).astype(np.float32)
    np.testing.assert_array_equal(got, want)


@given(
    b=st.integers(1, 6),
    n=st.integers(1, 40),
    f=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_macro_cell_mode_bit_identical(b, n, f, seed):
    """Eq. (3) two-cycle evaluation == ideal 8-bit comparison (Table I)."""
    rng = np.random.default_rng(seed)
    q, lo, hi, _ = random_case(rng, b, n, f, 1)
    macro_kernel = np.asarray(cam_match(q, lo, hi, mode="macro_cell"))
    macro_ref = np.asarray(cam_match_macro_ref(q, lo, hi)).astype(np.float32)
    ideal = np.asarray(cam_match_ref(q, lo, hi)).astype(np.float32)
    np.testing.assert_array_equal(macro_kernel, macro_ref)
    np.testing.assert_array_equal(macro_kernel, ideal)


@given(
    b=st.integers(1, 4),
    n=st.integers(1, 40),
    f=st.integers(1, 10),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_macro_cell_fused_matches_oracle(b, n, f, k, seed):
    rng = np.random.default_rng(seed)
    q, lo, hi, leaf = random_case(rng, b, n, f, k)
    got = cam_infer(q, lo, hi, leaf, mode="macro_cell")
    want = cam_infer_ref(q, lo, hi, leaf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_dont_care_row_matches_everything():
    q = jnp.asarray([[0, 255, 128]], dtype=jnp.int32)
    lo = jnp.zeros((1, 3), jnp.int32)
    hi = jnp.full((1, 3), 256, jnp.int32)
    assert np.asarray(cam_match(q, lo, hi))[0, 0] == 1.0


def test_padding_row_never_matches():
    q = jnp.asarray([[0], [255]], dtype=jnp.int32)
    lo = jnp.full((4, 1), 256, jnp.int32)
    hi = jnp.zeros((4, 1), jnp.int32)
    assert np.asarray(cam_match(q, lo, hi)).sum() == 0.0


def test_boundary_semantics():
    """lo inclusive, hi exclusive — the CAM window convention."""
    q = jnp.asarray([[9], [10], [19], [20]], dtype=jnp.int32)
    lo = jnp.asarray([[10]], dtype=jnp.int32)
    hi = jnp.asarray([[20]], dtype=jnp.int32)
    m = np.asarray(cam_match(q, lo, hi))[:, 0]
    np.testing.assert_array_equal(m, [0.0, 1.0, 1.0, 0.0])


@pytest.mark.parametrize("tb,tn", [(1, 1), (3, 7), (64, 256), (128, 512)])
def test_tile_shapes_do_not_change_results(tb, tn):
    rng = np.random.default_rng(7)
    q, lo, hi, leaf = random_case(rng, 8, 96, 11, 5)
    want = cam_infer_ref(q, lo, hi, leaf)
    got = cam_infer(q, lo, hi, leaf, tile_b=tb, tile_n=tn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_one_match_per_tree_yields_leaf_sum():
    """A disjoint partition of the query space (one tree) accumulates
    exactly the matched leaf — the §II-D mapping semantics."""
    # Two 'trees' of two rows each, partitioning q in [0,128) / [128,256).
    lo = jnp.asarray([[0], [128], [0], [64]], dtype=jnp.int32)
    hi = jnp.asarray([[128], [256], [64], [256]], dtype=jnp.int32)
    leaf = jnp.asarray([[1.0], [2.0], [10.0], [20.0]], dtype=jnp.float32)
    q = jnp.asarray([[30], [200]], dtype=jnp.int32)
    out = np.asarray(cam_infer(q, lo, hi, leaf))
    # q=30: rows 0 (+1) and 2 (+10); q=200: rows 1 (+2) and 3 (+20).
    np.testing.assert_allclose(out[:, 0], [11.0, 22.0])


def test_jit_cache_stable_across_calls():
    rng = np.random.default_rng(3)
    q, lo, hi, leaf = random_case(rng, 4, 32, 8, 4)
    a = np.asarray(cam_infer(q, lo, hi, leaf))
    b = np.asarray(cam_infer(q, lo, hi, leaf))
    np.testing.assert_array_equal(a, b)


def test_leaf_gradients_flow_through_reference():
    """The match is a hard indicator, so only `leaf` is differentiable —
    the quantity future co-design training (paper §V-A outlook) would
    optimize. Gradients are checked on the oracle graph (pallas_call has
    no registered AD rule; the AOT serving path never differentiates)."""
    rng = np.random.default_rng(5)
    q, lo, hi, leaf = random_case(rng, 2, 16, 4, 3)

    def loss(leaf_):
        return jnp.sum(cam_infer_ref(q, lo, hi, leaf_) ** 2)

    g = jax.grad(loss)(leaf)
    assert g.shape == leaf.shape
    assert np.isfinite(np.asarray(g)).all()
    # Gradient of a matched leaf equals 2·logit; unmatched leaves get 0.
    match = np.asarray(cam_match_ref(q, lo, hi))
    unmatched_rows = ~match.any(axis=0)
    np.testing.assert_array_equal(np.asarray(g)[unmatched_rows], 0.0)
