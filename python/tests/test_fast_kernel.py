"""Perf-pass kernel (`cam_infer_fast`, u8/transposed layout) must agree
with the oracle and the hardware-mode kernel exactly."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile.kernels.cam_match import cam_infer, cam_infer_fast
from compile.kernels.ref import cam_infer_ref


def to_fast(q, lo, hi):
    """Convert exclusive-i32 inputs to the fast kernel's u8 layout."""
    qt = jnp.asarray(np.asarray(q).T, jnp.uint8)
    lo8 = jnp.asarray(np.asarray(lo), jnp.uint8)
    # hi is exclusive in 0..=256; inclusive u8 encoding: hi-1 (clamped so
    # never-match rows hi=0 stay below lo=255).
    hi8 = jnp.asarray(np.clip(np.asarray(hi) - 1, 0, 255), jnp.uint8)
    return qt, lo8, hi8


def random_case(rng, b, n, f, k):
    q = rng.integers(0, 256, size=(b, f), dtype=np.int32)
    lo = rng.integers(0, 200, size=(n, f)).astype(np.int32)
    hi = np.minimum(lo + rng.integers(1, 80, size=(n, f)), 256).astype(np.int32)
    dc = rng.random((n, f)) < 0.2
    lo[dc], hi[dc] = 0, 256
    nm = rng.random(n) < 0.05
    lo[nm, :], hi[nm, :] = 256, 0
    leaf = rng.standard_normal((n, k)).astype(np.float32)
    leaf[nm, :] = 0.0
    return jnp.asarray(q), jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(leaf)


@given(
    b=st.integers(1, 8),
    n=st.integers(1, 64),
    f=st.integers(1, 16),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fast_kernel_matches_oracle(b, n, f, k, seed):
    rng = np.random.default_rng(seed)
    q, lo, hi, leaf = random_case(rng, b, n, f, k)
    qt, lo8, hi8 = to_fast(q, lo, hi)
    got = np.asarray(cam_infer_fast(qt, lo8, hi8, leaf)).T  # [K,B] → [B,K]
    want = np.asarray(cam_infer_ref(q, lo, hi, leaf))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(
    b=st.integers(1, 4),
    n=st.integers(1, 48),
    f=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_fast_equals_hardware_mode_kernel(b, n, f, seed):
    rng = np.random.default_rng(seed)
    q, lo, hi, leaf = random_case(rng, b, n, f, 4)
    qt, lo8, hi8 = to_fast(q, lo, hi)
    fast = np.asarray(cam_infer_fast(qt, lo8, hi8, leaf)).T
    hw = np.asarray(cam_infer(q, lo, hi, leaf, mode="macro_cell"))
    np.testing.assert_allclose(fast, hw, rtol=1e-6, atol=1e-6)


def test_fast_padding_rows_never_match():
    qt = jnp.zeros((3, 2), jnp.uint8)
    lo = jnp.full((8, 3), 255, jnp.uint8)
    hi = jnp.zeros((8, 3), jnp.uint8)
    leaf = jnp.ones((8, 2), jnp.float32)
    out = np.asarray(cam_infer_fast(qt, lo, hi, leaf))
    np.testing.assert_array_equal(out, 0.0)


def test_fast_inclusive_boundary():
    # Window [10, 20) exclusive == [10, 19] inclusive in u8 encoding.
    qt = jnp.asarray([[9, 10, 19, 20]], jnp.uint8).reshape(1, 4)
    lo = jnp.asarray([[10]], jnp.uint8)
    hi = jnp.asarray([[19]], jnp.uint8)
    leaf = jnp.asarray([[1.0]], jnp.float32)
    out = np.asarray(cam_infer_fast(qt, lo, hi, leaf))[0]
    np.testing.assert_array_equal(out, [0.0, 1.0, 1.0, 0.0])


def test_fast_tile_invariance():
    rng = np.random.default_rng(3)
    q, lo, hi, leaf = random_case(rng, 8, 96, 11, 5)
    qt, lo8, hi8 = to_fast(q, lo, hi)
    a = np.asarray(cam_infer_fast(qt, lo8, hi8, leaf, tile_n=8))
    b = np.asarray(cam_infer_fast(qt, lo8, hi8, leaf, tile_n=96))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
